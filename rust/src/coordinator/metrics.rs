//! Pipeline metrics: per-frame records and the aggregated report.
//!
//! With band sharding a "frame record" is the merge of its bands:
//! latency spans first emit to last band completion, queue wait is the
//! worst band's, compute is the summed engine time, and hardware
//! [`RunStats`] (engines that model them) merge across bands via
//! [`RunStats::merge`].

use std::time::Duration;

use crate::sim::RunStats;
use crate::util::stats::Summary;

/// Timing of one frame through the pipeline.
#[derive(Clone, Debug)]
pub struct FrameRecord {
    pub index: usize,
    /// Time from first band emit to last band completion.
    pub latency: Duration,
    /// Worst band's wait in the input queue.
    pub queue_wait: Duration,
    /// Total engine time summed over bands (exceeds latency when bands
    /// run in parallel).
    pub compute: Duration,
    /// Bands this frame was split into (1 = whole-frame).
    pub bands: usize,
    /// Merged hardware stats of the frame's bands, if the engine
    /// models them.
    pub stats: Option<RunStats>,
}

/// Aggregated serving report (printed by `sr-accel serve` and logged in
/// EXPERIMENTS.md E7).
#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub frames: usize,
    pub wall: Duration,
    pub fps: f64,
    pub latency_ms: Summary,
    pub queue_wait_ms: Summary,
    pub compute_ms: Summary,
    pub engine: String,
    pub workers: usize,
    /// HR megapixels per second of wall time.
    pub mpix_per_s: f64,
    /// Shard-plan description (`ShardPlan::describe`).
    pub plan: String,
    /// Hardware stats merged across all frames (None for engines that
    /// do not model hardware).
    pub hw: Option<RunStats>,
}

impl PipelineReport {
    pub fn from_records(
        records: &[FrameRecord],
        wall: Duration,
        engine: &str,
        workers: usize,
        hr_pixels_per_frame: usize,
        plan: &str,
    ) -> Self {
        let to_ms = |d: &Duration| d.as_secs_f64() * 1e3;
        let fps = records.len() as f64 / wall.as_secs_f64().max(1e-12);
        let mut hw: Option<RunStats> = None;
        for r in records {
            if let Some(s) = &r.stats {
                match &mut hw {
                    Some(acc) => acc.merge(s),
                    None => hw = Some(s.clone()),
                }
            }
        }
        Self {
            frames: records.len(),
            wall,
            fps,
            latency_ms: Summary::from_samples(
                records.iter().map(|r| to_ms(&r.latency)).collect(),
            ),
            queue_wait_ms: Summary::from_samples(
                records.iter().map(|r| to_ms(&r.queue_wait)).collect(),
            ),
            compute_ms: Summary::from_samples(
                records.iter().map(|r| to_ms(&r.compute)).collect(),
            ),
            engine: engine.to_string(),
            workers,
            mpix_per_s: fps * hr_pixels_per_frame as f64 / 1e6,
            plan: plan.to_string(),
            hw,
        }
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "engine={} workers={} plan={} frames={} wall={:.2}s\n\
             throughput: {:.2} fps  ({:.1} HR Mpix/s)\n\
             latency  ms: p50 {:.2}  p95 {:.2}  max {:.2}\n\
             queue-wait ms: p50 {:.2}  p95 {:.2}\n\
             compute  ms: p50 {:.2}  p95 {:.2}",
            self.engine,
            self.workers,
            self.plan,
            self.frames,
            self.wall.as_secs_f64(),
            self.fps,
            self.mpix_per_s,
            self.latency_ms.median(),
            self.latency_ms.percentile(95.0),
            self.latency_ms.max(),
            self.queue_wait_ms.median(),
            self.queue_wait_ms.percentile(95.0),
            self.compute_ms.median(),
            self.compute_ms.percentile(95.0),
        );
        if let Some(hw) = &self.hw {
            let frames = self.frames.max(1) as f64;
            out.push_str(&format!(
                "\nhw: {:.2} Mcycles/frame  util {:.1} %  \
                 dram {:.2} MB/frame  {} tiles",
                hw.compute_cycles as f64 / frames / 1e6,
                hw.utilization() * 100.0,
                hw.dram_total_bytes() as f64 / frames / 1e6,
                hw.tiles,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: usize, ms: u64) -> FrameRecord {
        FrameRecord {
            index: i,
            latency: Duration::from_millis(ms),
            queue_wait: Duration::from_millis(ms / 4),
            compute: Duration::from_millis(ms / 2),
            bands: 1,
            stats: None,
        }
    }

    #[test]
    fn report_aggregates() {
        let records: Vec<_> = (0..10).map(|i| rec(i, 10 + i as u64)).collect();
        let rep = PipelineReport::from_records(
            &records,
            Duration::from_secs(1),
            "int8",
            2,
            1920 * 1080,
            "whole-frame",
        );
        assert_eq!(rep.frames, 10);
        assert!((rep.fps - 10.0).abs() < 1e-9);
        assert!(rep.latency_ms.median() >= 10.0);
        assert!((rep.mpix_per_s - 20.736).abs() < 1e-3);
        assert!(rep.hw.is_none());
        assert!(rep.render().contains("throughput"));
        assert!(rep.render().contains("plan=whole-frame"));
        assert!(!rep.render().contains("hw:"));
    }

    #[test]
    fn report_merges_hw_stats_across_frames() {
        let records: Vec<_> = (0..4)
            .map(|i| FrameRecord {
                stats: Some(RunStats {
                    compute_cycles: 1000,
                    mac_ops: 80,
                    mac_slots: 100,
                    tiles: 3,
                    ..RunStats::default()
                }),
                bands: 2,
                ..rec(i, 10)
            })
            .collect();
        let rep = PipelineReport::from_records(
            &records,
            Duration::from_secs(1),
            "sim",
            2,
            100,
            "row-bands(rows=6, halo=none, affinity=any)",
        );
        let hw = rep.hw.as_ref().unwrap();
        assert_eq!(hw.compute_cycles, 4000);
        assert_eq!(hw.tiles, 12);
        assert!((hw.utilization() - 0.8).abs() < 1e-12);
        assert!(rep.render().contains("hw:"));
        assert!(rep.render().contains("row-bands"));
    }
}
