//! Pipeline metrics: per-frame records and the aggregated report.

use std::time::Duration;

use crate::util::stats::Summary;

/// Timing of one frame through the pipeline.
#[derive(Clone, Debug)]
pub struct FrameRecord {
    pub index: usize,
    /// Time from source emit to completion.
    pub latency: Duration,
    /// Time spent waiting in the input queue.
    pub queue_wait: Duration,
    /// Pure engine time.
    pub compute: Duration,
}

/// Aggregated serving report (printed by `sr-accel serve` and logged in
/// EXPERIMENTS.md E7).
#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub frames: usize,
    pub wall: Duration,
    pub fps: f64,
    pub latency_ms: Summary,
    pub queue_wait_ms: Summary,
    pub compute_ms: Summary,
    pub engine: String,
    pub workers: usize,
    /// HR megapixels per second of wall time.
    pub mpix_per_s: f64,
}

impl PipelineReport {
    pub fn from_records(
        records: &[FrameRecord],
        wall: Duration,
        engine: &str,
        workers: usize,
        hr_pixels_per_frame: usize,
    ) -> Self {
        let to_ms =
            |d: &Duration| d.as_secs_f64() * 1e3;
        let fps = records.len() as f64 / wall.as_secs_f64().max(1e-12);
        Self {
            frames: records.len(),
            wall,
            fps,
            latency_ms: Summary::from_samples(
                records.iter().map(|r| to_ms(&r.latency)).collect(),
            ),
            queue_wait_ms: Summary::from_samples(
                records.iter().map(|r| to_ms(&r.queue_wait)).collect(),
            ),
            compute_ms: Summary::from_samples(
                records.iter().map(|r| to_ms(&r.compute)).collect(),
            ),
            engine: engine.to_string(),
            workers,
            mpix_per_s: fps * hr_pixels_per_frame as f64 / 1e6,
        }
    }

    pub fn render(&self) -> String {
        format!(
            "engine={} workers={} frames={} wall={:.2}s\n\
             throughput: {:.2} fps  ({:.1} HR Mpix/s)\n\
             latency  ms: p50 {:.2}  p95 {:.2}  max {:.2}\n\
             queue-wait ms: p50 {:.2}  p95 {:.2}\n\
             compute  ms: p50 {:.2}  p95 {:.2}",
            self.engine,
            self.workers,
            self.frames,
            self.wall.as_secs_f64(),
            self.fps,
            self.mpix_per_s,
            self.latency_ms.median(),
            self.latency_ms.percentile(95.0),
            self.latency_ms.max(),
            self.queue_wait_ms.median(),
            self.queue_wait_ms.percentile(95.0),
            self.compute_ms.median(),
            self.compute_ms.percentile(95.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: usize, ms: u64) -> FrameRecord {
        FrameRecord {
            index: i,
            latency: Duration::from_millis(ms),
            queue_wait: Duration::from_millis(ms / 4),
            compute: Duration::from_millis(ms / 2),
        }
    }

    #[test]
    fn report_aggregates() {
        let records: Vec<_> = (0..10).map(|i| rec(i, 10 + i as u64)).collect();
        let rep = PipelineReport::from_records(
            &records,
            Duration::from_secs(1),
            "int8",
            2,
            1920 * 1080,
        );
        assert_eq!(rep.frames, 10);
        assert!((rep.fps - 10.0).abs() < 1e-9);
        assert!(rep.latency_ms.median() >= 10.0);
        assert!((rep.mpix_per_s - 20.736).abs() < 1e-3);
        assert!(rep.render().contains("throughput"));
    }
}
