//! Pipeline metrics: per-frame records, per-stream delivery summaries
//! and the aggregated report.
//!
//! With band sharding a "frame record" is the merge of its bands:
//! latency spans first emit to last band completion, queue wait is the
//! worst band's, compute is the summed engine time, and hardware
//! [`RunStats`] (engines that model them) merge across bands via
//! [`RunStats::merge`].
//!
//! With multi-stream serving (`coordinator::server`) every record also
//! carries its stream id, and the report breaks delivery down per
//! stream ([`StreamSummary`]): mixed geometries mean a single
//! pixels-per-frame scalar cannot express throughput, so HR Mpix/s is
//! accumulated per stream and summed for the aggregate.  Frames a
//! stream *offered* but that were neither delivered nor dropped —
//! e.g. lost inside a dead worker, or parked behind such a loss — are
//! surfaced as `incomplete` instead of silently missing from `frames`.

use std::time::Duration;

use crate::sim::RunStats;
use crate::util::stats::Summary;

/// Rung of the `RtPolicy::Degrade` quality ladder a band/frame was
/// served at.  Ordered: reassembly taints a frame with the *worst*
/// (`max`) rung among its bands.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum QualityLevel {
    /// Full-quality SR at the stream's native scale.
    Full,
    /// Scale-downshift: SR at x2, bilinear-expanded the rest of the
    /// way to the stream's target geometry (ladder rung 1).
    Reduced,
    /// Pure bilinear upsample — no model at all (ladder rung 2).
    Bilinear,
}

impl QualityLevel {
    pub fn name(self) -> &'static str {
        match self {
            QualityLevel::Full => "full",
            QualityLevel::Reduced => "reduced",
            QualityLevel::Bilinear => "bilinear",
        }
    }

    /// Anything below full quality counts as degraded delivery.
    pub fn is_degraded(self) -> bool {
        self != QualityLevel::Full
    }
}

/// Timing of one frame through the pipeline.
#[derive(Clone, Debug)]
pub struct FrameRecord {
    /// Stream this frame belongs to (0 for single-stream pipelines).
    pub stream: usize,
    pub index: usize,
    /// Time from first band emit to last band completion.
    pub latency: Duration,
    /// Worst band's wait in the input queue.
    pub queue_wait: Duration,
    /// Total engine time summed over bands (exceeds latency when bands
    /// run in parallel).
    pub compute: Duration,
    /// Bands this frame was split into (1 = whole-frame).
    pub bands: usize,
    /// Merged hardware stats of the frame's bands, if the engine
    /// models them.
    pub stats: Option<RunStats>,
    /// Worst degradation-ladder rung among the frame's bands
    /// (`RtPolicy::Degrade` downshift).
    pub level: QualityLevel,
}

/// Identity and source-side accounting of one stream, supplied by the
/// pipeline (single-stream runs pass exactly one).
#[derive(Clone, Debug)]
pub struct StreamMeta {
    /// Stream id — must equal the `stream` field of its records.
    pub id: usize,
    /// Human-readable identity (the stream-spec string).
    pub label: String,
    pub lr_w: usize,
    pub lr_h: usize,
    pub scale: usize,
    /// Frames the source actually generated for this stream.
    pub offered: usize,
    /// Frames shed by the drop policy (admission or deadline).
    pub dropped: usize,
}

impl StreamMeta {
    pub fn hr_pixels(&self) -> usize {
        self.lr_w * self.scale * self.lr_h * self.scale
    }
}

/// Per-stream delivery summary derived from the frame records.
#[derive(Clone, Debug)]
pub struct StreamSummary {
    pub meta: StreamMeta,
    /// Frames handed to `on_frame` in display order.
    pub delivered: usize,
    /// Offered but neither delivered nor dropped (lost to a dead
    /// worker, or parked behind such a loss).
    pub incomplete: usize,
    /// Delivered below full quality — a subset of `delivered`, never
    /// of `dropped`.
    pub degraded: usize,
    /// Breakdown of `degraded` by ladder rung: `[reduced, bilinear]`.
    pub degraded_by_level: [usize; 2],
    /// `dropped / offered` (0 when nothing was offered).
    pub drop_rate: f64,
    /// `degraded / offered` (0 when nothing was offered).
    pub degrade_rate: f64,
    pub latency_ms: Summary,
    /// Delivered HR megapixels per second of wall time.
    pub mpix_per_s: f64,
}

/// Aggregated serving report (printed by `sr-accel serve` /
/// `serve-multi` and logged in EXPERIMENTS.md E7).
#[derive(Clone, Debug)]
pub struct PipelineReport {
    /// Frames delivered in display order, across all streams.
    pub frames: usize,
    pub wall: Duration,
    pub fps: f64,
    pub latency_ms: Summary,
    pub queue_wait_ms: Summary,
    pub compute_ms: Summary,
    /// Stable engine rendering: the single name when all workers
    /// agree, else per-worker names joined with `+` in worker order.
    pub engine: String,
    /// Per-worker engine names, indexed by worker id.  An empty slot
    /// means the worker never built an engine: it failed before
    /// construction, or — under a drop policy — only ever shed
    /// already-late frames (check [`PipelineReport::errors`] to tell
    /// the two apart).
    pub engines: Vec<String>,
    pub workers: usize,
    /// Kernel ISA the dispatch layer selected on this host
    /// (`"avx512" | "avx2" | "neon" | "scalar"` — §Multi-ISA).  The
    /// same truth the benches emit as `extra.isa`; `"scalar"` on a
    /// vector-capable host means detection found nothing usable, not
    /// that `force_scalar` was requested.
    pub isa: String,
    /// Aggregate delivered HR megapixels per second of wall time.
    pub mpix_per_s: f64,
    /// Shard/serving-plan description.
    pub plan: String,
    /// Where the plan came from: `"default"` for today's built-in
    /// defaults / explicit CLI-config knobs, or `"cache:<key>"` when
    /// the autotuned plan cache supplied it (§Autotuned planner) — so
    /// reports are self-describing about what was applied.
    pub plan_source: String,
    /// Frames shed by the drop policy, across all streams.
    pub dropped: usize,
    /// Frames offered but neither delivered nor dropped.
    pub incomplete: usize,
    /// Frames delivered below full quality, across all streams —
    /// counted inside `frames`, not alongside it.
    pub degraded: usize,
    /// Breakdown of `degraded` by ladder rung: `[reduced, bilinear]`.
    pub degraded_by_level: [usize; 2],
    /// `dropped / offered` across all streams.
    pub drop_rate: f64,
    /// `degraded / offered` across all streams.
    pub degrade_rate: f64,
    /// Worker restarts the supervisor performed (`RestartPolicy`),
    /// summed across workers — fail-fast rebuilds *and* hung-worker
    /// replacements.  Set by the pipeline after `from_records`, like
    /// `errors`.
    pub restarts: usize,
    /// Workers the watchdog zombified for exceeding the stall budget.
    /// Set by the pipeline after `from_records`.
    pub hangs_detected: usize,
    /// Late results from zombified worker generations that were
    /// discarded instead of double-delivered.  Set by the pipeline
    /// after `from_records`.
    pub zombies_reaped: usize,
    /// Per-stream breakdown (single-stream runs have exactly one).
    pub streams: Vec<StreamSummary>,
    /// Worker errors — a report with errors is partial.
    pub errors: Vec<String>,
    /// Hardware stats merged across all frames (None for engines that
    /// do not model hardware).
    pub hw: Option<RunStats>,
}

/// Stable engine-name rendering: empty slots (a worker that never
/// built an engine — early failure, or a drop-policy worker that only
/// shed frames) show as `?`.
fn render_engines(engines: &[String]) -> String {
    let shown: Vec<&str> = engines
        .iter()
        .map(|e| if e.is_empty() { "?" } else { e.as_str() })
        .collect();
    match shown.first() {
        None => "?".to_string(),
        Some(first) if shown.iter().all(|e| e == first) => {
            (*first).to_string()
        }
        _ => shown.join("+"),
    }
}

impl PipelineReport {
    pub fn from_records(
        records: &[FrameRecord],
        wall: Duration,
        engines: &[String],
        workers: usize,
        plan: &str,
        streams: Vec<StreamMeta>,
    ) -> Self {
        let to_ms = |d: &Duration| d.as_secs_f64() * 1e3;
        let secs = wall.as_secs_f64().max(1e-12);
        let fps = records.len() as f64 / secs;
        let mut hw: Option<RunStats> = None;
        for r in records {
            if let Some(s) = &r.stats {
                match &mut hw {
                    Some(acc) => acc.merge(s),
                    None => hw = Some(s.clone()),
                }
            }
        }
        let mut hr_px_total = 0.0f64;
        let summaries: Vec<StreamSummary> = streams
            .into_iter()
            .map(|meta| {
                let latencies: Vec<f64> = records
                    .iter()
                    .filter(|r| r.stream == meta.id)
                    .map(|r| to_ms(&r.latency))
                    .collect();
                let by_level = |lvl: QualityLevel| {
                    records
                        .iter()
                        .filter(|r| r.stream == meta.id && r.level == lvl)
                        .count()
                };
                let degraded_by_level = [
                    by_level(QualityLevel::Reduced),
                    by_level(QualityLevel::Bilinear),
                ];
                let degraded = degraded_by_level.iter().sum();
                let delivered = latencies.len();
                let hr_px = meta.hr_pixels() as f64 * delivered as f64;
                hr_px_total += hr_px;
                StreamSummary {
                    delivered,
                    incomplete: meta
                        .offered
                        .saturating_sub(meta.dropped + delivered),
                    degraded,
                    degraded_by_level,
                    drop_rate: rate(meta.dropped, meta.offered),
                    degrade_rate: rate(degraded, meta.offered),
                    latency_ms: Summary::from_samples(latencies),
                    mpix_per_s: hr_px / secs / 1e6,
                    meta,
                }
            })
            .collect();
        let offered: usize = summaries.iter().map(|s| s.meta.offered).sum();
        let dropped: usize = summaries.iter().map(|s| s.meta.dropped).sum();
        let incomplete: usize =
            summaries.iter().map(|s| s.incomplete).sum();
        let degraded: usize = summaries.iter().map(|s| s.degraded).sum();
        let degraded_by_level = [
            summaries.iter().map(|s| s.degraded_by_level[0]).sum(),
            summaries.iter().map(|s| s.degraded_by_level[1]).sum(),
        ];
        Self {
            frames: records.len(),
            wall,
            fps,
            latency_ms: Summary::from_samples(
                records.iter().map(|r| to_ms(&r.latency)).collect(),
            ),
            queue_wait_ms: Summary::from_samples(
                records.iter().map(|r| to_ms(&r.queue_wait)).collect(),
            ),
            compute_ms: Summary::from_samples(
                records.iter().map(|r| to_ms(&r.compute)).collect(),
            ),
            engine: render_engines(engines),
            engines: engines.to_vec(),
            workers,
            isa: crate::reference::Isa::detected().name().to_string(),
            mpix_per_s: hr_px_total / secs / 1e6,
            plan: plan.to_string(),
            plan_source: "default".to_string(),
            dropped,
            incomplete,
            degraded,
            degraded_by_level,
            drop_rate: rate(dropped, offered),
            degrade_rate: rate(degraded, offered),
            restarts: 0,
            hangs_detected: 0,
            zombies_reaped: 0,
            streams: summaries,
            errors: Vec::new(),
            hw,
        }
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "engine={} isa={} workers={} plan={} plan-src={} frames={} \
             wall={:.2}s\n\
             throughput: {:.2} fps  ({:.1} HR Mpix/s)\n\
             latency  ms: p50 {:.2}  p95 {:.2}  max {:.2}\n\
             queue-wait ms: p50 {:.2}  p95 {:.2}\n\
             compute  ms: p50 {:.2}  p95 {:.2}",
            self.engine,
            self.isa,
            self.workers,
            self.plan,
            self.plan_source,
            self.frames,
            self.wall.as_secs_f64(),
            self.fps,
            self.mpix_per_s,
            self.latency_ms.median(),
            self.latency_ms.percentile(95.0),
            self.latency_ms.max(),
            self.queue_wait_ms.median(),
            self.queue_wait_ms.percentile(95.0),
            self.compute_ms.median(),
            self.compute_ms.percentile(95.0),
        );
        if self.dropped > 0 || self.incomplete > 0 || self.degraded > 0 {
            out.push_str(&format!(
                "\ndelivery: {} delivered  {} dropped ({:.1} %)  \
                 {} incomplete",
                self.frames,
                self.dropped,
                self.drop_rate * 100.0,
                self.incomplete,
            ));
            if self.degraded > 0 {
                out.push_str(&format!(
                    "  {} degraded ({:.1} %)",
                    self.degraded,
                    self.degrade_rate * 100.0,
                ));
                if self.degraded_by_level[0] > 0 {
                    out.push_str(&format!(
                        " [{} reduced, {} bilinear]",
                        self.degraded_by_level[0],
                        self.degraded_by_level[1],
                    ));
                }
            }
        }
        if self.restarts > 0 {
            out.push_str(&format!(
                "\nsupervisor: {} worker restart{}",
                self.restarts,
                if self.restarts == 1 { "" } else { "s" },
            ));
        }
        if self.hangs_detected > 0 || self.zombies_reaped > 0 {
            out.push_str(&format!(
                "\nwatchdog: {} hang{} detected  {} zombie result{} \
                 discarded",
                self.hangs_detected,
                if self.hangs_detected == 1 { "" } else { "s" },
                self.zombies_reaped,
                if self.zombies_reaped == 1 { "" } else { "s" },
            ));
        }
        if self.streams.len() > 1 {
            for s in &self.streams {
                out.push_str(&format!(
                    "\n  stream {} [{}] {}x{}@x{}: {}/{} delivered  \
                     drop {:.1} %  p95 {:.2} ms  {:.1} Mpix/s",
                    s.meta.id,
                    s.meta.label,
                    s.meta.lr_w,
                    s.meta.lr_h,
                    s.meta.scale,
                    s.delivered,
                    s.meta.offered,
                    s.drop_rate * 100.0,
                    s.latency_ms.percentile(95.0),
                    s.mpix_per_s,
                ));
                if s.degraded > 0 {
                    out.push_str(&format!(
                        "  degraded {}/{}",
                        s.degraded, s.delivered,
                    ));
                }
            }
        }
        if !self.errors.is_empty() {
            out.push_str(&format!(
                "\nworker errors ({}): {}",
                self.errors.len(),
                self.errors.join("; ")
            ));
        }
        if let Some(hw) = &self.hw {
            let frames = self.frames.max(1) as f64;
            out.push_str(&format!(
                "\nhw: {:.2} Mcycles/frame  util {:.1} %  \
                 dram {:.2} MB/frame  {} tiles",
                hw.compute_cycles as f64 / frames / 1e6,
                hw.utilization() * 100.0,
                hw.dram_total_bytes() as f64 / frames / 1e6,
                hw.tiles,
            ));
        }
        out
    }
}

fn rate(part: usize, whole: usize) -> f64 {
    if whole == 0 {
        0.0
    } else {
        part as f64 / whole as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: usize, ms: u64) -> FrameRecord {
        FrameRecord {
            stream: 0,
            index: i,
            latency: Duration::from_millis(ms),
            queue_wait: Duration::from_millis(ms / 4),
            compute: Duration::from_millis(ms / 2),
            bands: 1,
            stats: None,
            level: QualityLevel::Full,
        }
    }

    fn meta(id: usize, lr_w: usize, lr_h: usize, scale: usize) -> StreamMeta {
        StreamMeta {
            id,
            label: format!("{lr_w}x{lr_h}@x{scale}"),
            lr_w,
            lr_h,
            scale,
            offered: 0,
            dropped: 0,
        }
    }

    fn names(n: &[&str]) -> Vec<String> {
        n.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn report_aggregates() {
        let records: Vec<_> = (0..10).map(|i| rec(i, 10 + i as u64)).collect();
        let rep = PipelineReport::from_records(
            &records,
            Duration::from_secs(1),
            &names(&["int8", "int8"]),
            2,
            "whole-frame",
            vec![StreamMeta {
                offered: 10,
                ..meta(0, 640, 360, 3)
            }],
        );
        assert_eq!(rep.frames, 10);
        assert!((rep.fps - 10.0).abs() < 1e-9);
        assert!(rep.latency_ms.median() >= 10.0);
        assert!((rep.mpix_per_s - 20.736).abs() < 1e-3);
        assert_eq!(rep.engine, "int8");
        assert_eq!(rep.dropped, 0);
        assert_eq!(rep.incomplete, 0);
        assert_eq!(rep.streams.len(), 1);
        assert_eq!(rep.streams[0].delivered, 10);
        assert!((rep.streams[0].mpix_per_s - rep.mpix_per_s).abs() < 1e-9);
        assert!(rep.hw.is_none());
        assert!(rep.render().contains("throughput"));
        assert!(rep.render().contains("plan=whole-frame"));
        // plan provenance defaults to "default" and renders; callers
        // (serve) overwrite it when the autotuned cache supplied the plan
        assert_eq!(rep.plan_source, "default");
        assert!(rep.render().contains("plan-src=default"));
        let mut cached = rep.clone();
        cached.plan_source = "cache:640x360x3_avx2_w2".into();
        assert!(cached
            .render()
            .contains("plan-src=cache:640x360x3_avx2_w2"));
        // the report names the dispatched kernel ISA
        assert!(["scalar", "avx2", "avx512", "neon"]
            .contains(&rep.isa.as_str()));
        assert!(rep.render().contains(&format!("isa={}", rep.isa)));
        assert!(!rep.render().contains("hw:"));
        assert!(!rep.render().contains("delivery:"));
        assert!(!rep.render().contains("worker errors"));
    }

    #[test]
    fn report_merges_hw_stats_across_frames() {
        let records: Vec<_> = (0..4)
            .map(|i| FrameRecord {
                stats: Some(RunStats {
                    compute_cycles: 1000,
                    mac_ops: 80,
                    mac_slots: 100,
                    tiles: 3,
                    ..RunStats::default()
                }),
                bands: 2,
                ..rec(i, 10)
            })
            .collect();
        let rep = PipelineReport::from_records(
            &records,
            Duration::from_secs(1),
            &names(&["sim", "sim"]),
            2,
            "row-bands(rows=6, halo=none, affinity=any)",
            vec![StreamMeta {
                offered: 4,
                ..meta(0, 10, 10, 1)
            }],
        );
        let hw = rep.hw.as_ref().unwrap();
        assert_eq!(hw.compute_cycles, 4000);
        assert_eq!(hw.tiles, 12);
        assert!((hw.utilization() - 0.8).abs() < 1e-12);
        assert!(rep.render().contains("hw:"));
        assert!(rep.render().contains("row-bands"));
    }

    #[test]
    fn heterogeneous_engine_names_render_stably() {
        assert_eq!(render_engines(&[]), "?");
        assert_eq!(render_engines(&names(&["int8"])), "int8");
        assert_eq!(render_engines(&names(&["int8", "int8"])), "int8");
        assert_eq!(render_engines(&names(&["int8", "sim"])), "int8+sim");
        assert_eq!(
            render_engines(&names(&["int8", "", "sim"])),
            "int8+?+sim"
        );
    }

    #[test]
    fn multi_stream_report_attributes_pixels_per_stream() {
        // stream 0: 10x10 @ x2 (400 HR px/frame), 3 delivered
        // stream 1: 20x10 @ x3 (1800 HR px/frame), 2 delivered
        let mut records: Vec<_> =
            (0..3).map(|i| FrameRecord { stream: 0, ..rec(i, 8) }).collect();
        records.extend(
            (0..2).map(|i| FrameRecord { stream: 1, ..rec(i, 20) }),
        );
        let rep = PipelineReport::from_records(
            &records,
            Duration::from_secs(1),
            &names(&["int8"]),
            1,
            "multi-stream(2 streams, policy=best-effort)",
            vec![
                StreamMeta {
                    offered: 3,
                    ..meta(0, 10, 10, 2)
                },
                StreamMeta {
                    offered: 4,
                    dropped: 1,
                    ..meta(1, 20, 10, 3)
                },
            ],
        );
        assert_eq!(rep.frames, 5);
        assert_eq!(rep.streams.len(), 2);
        let (s0, s1) = (&rep.streams[0], &rep.streams[1]);
        assert_eq!((s0.delivered, s0.incomplete), (3, 0));
        assert!((s0.mpix_per_s - 3.0 * 400.0 / 1e6).abs() < 1e-12);
        // stream 1: 4 offered = 2 delivered + 1 dropped + 1 incomplete
        assert_eq!((s1.delivered, s1.incomplete), (2, 1));
        assert!((s1.drop_rate - 0.25).abs() < 1e-12);
        assert!((s1.mpix_per_s - 2.0 * 1800.0 / 1e6).abs() < 1e-12);
        // aggregate sums the per-stream pixel rates
        assert!(
            (rep.mpix_per_s - (s0.mpix_per_s + s1.mpix_per_s)).abs() < 1e-12
        );
        assert_eq!(rep.dropped, 1);
        assert_eq!(rep.incomplete, 1);
        assert!((rep.drop_rate - 1.0 / 7.0).abs() < 1e-12);
        // per-stream latency summaries split correctly
        assert!((s0.latency_ms.max() - 8.0).abs() < 1e-9);
        assert!((s1.latency_ms.max() - 20.0).abs() < 1e-9);
        let r = rep.render();
        assert!(r.contains("delivery: 5 delivered  1 dropped"));
        assert!(r.contains("stream 0 [10x10@x2]"));
        assert!(r.contains("stream 1 [20x10@x3]"));
    }

    #[test]
    fn degraded_frames_are_counted_inside_delivered() {
        // stream 0: 4 delivered, 2 of them degraded; stream 1: clean
        let mut records: Vec<_> = (0..4)
            .map(|i| FrameRecord {
                stream: 0,
                level: if i % 2 == 0 {
                    QualityLevel::Bilinear
                } else {
                    QualityLevel::Full
                },
                ..rec(i, 10)
            })
            .collect();
        records.extend(
            (0..3).map(|i| FrameRecord { stream: 1, ..rec(i, 10) }),
        );
        let mut rep = PipelineReport::from_records(
            &records,
            Duration::from_secs(1),
            &names(&["int8"]),
            1,
            "multi-stream(2 streams, policy=degrade:5)",
            vec![
                StreamMeta {
                    offered: 4,
                    ..meta(0, 10, 10, 2)
                },
                StreamMeta {
                    offered: 3,
                    ..meta(1, 10, 10, 2)
                },
            ],
        );
        rep.restarts = 1;
        // degraded frames stay inside delivered: nothing is undelivered
        assert_eq!(rep.frames, 7);
        assert_eq!(rep.degraded, 2);
        assert_eq!((rep.dropped, rep.incomplete), (0, 0));
        assert!((rep.degrade_rate - 2.0 / 7.0).abs() < 1e-12);
        assert_eq!(rep.streams[0].degraded, 2);
        assert!((rep.streams[0].degrade_rate - 0.5).abs() < 1e-12);
        assert_eq!(rep.streams[1].degraded, 0);
        // all-bilinear degradation: no reduced rung in the breakdown
        assert_eq!(rep.degraded_by_level, [0, 2]);
        assert_eq!(rep.streams[0].degraded_by_level, [0, 2]);
        let r = rep.render();
        assert!(r.contains("delivery: 7 delivered  0 dropped"));
        assert!(r.contains("2 degraded (28.6 %)"));
        assert!(r.contains("degraded 2/4"));
        assert!(r.contains("supervisor: 1 worker restart"));
        // a fully clean run still omits the delivery/supervisor lines
        rep.restarts = 0;
        rep.degraded = 0;
        rep.streams[0].degraded = 0;
        let clean = rep.render();
        assert!(!clean.contains("delivery:"));
        assert!(!clean.contains("supervisor:"));
    }

    #[test]
    fn ladder_levels_break_down_and_watchdog_line_renders() {
        let levels = [
            QualityLevel::Full,
            QualityLevel::Reduced,
            QualityLevel::Reduced,
            QualityLevel::Bilinear,
        ];
        let records: Vec<_> = levels
            .iter()
            .enumerate()
            .map(|(i, &level)| FrameRecord { level, ..rec(i, 10) })
            .collect();
        let mut rep = PipelineReport::from_records(
            &records,
            Duration::from_secs(1),
            &names(&["int8"]),
            1,
            "whole-frame",
            vec![StreamMeta {
                offered: 4,
                ..meta(0, 10, 10, 4)
            }],
        );
        assert_eq!(rep.degraded, 3);
        assert_eq!(rep.degraded_by_level, [2, 1]);
        assert!((rep.degrade_rate - 0.75).abs() < 1e-12);
        let r = rep.render();
        assert!(r.contains("3 degraded (75.0 %) [2 reduced, 1 bilinear]"));
        // the watchdog line appears only once something was reaped
        assert!(!r.contains("watchdog:"));
        rep.hangs_detected = 1;
        rep.zombies_reaped = 1;
        let r = rep.render();
        assert!(
            r.contains("watchdog: 1 hang detected  1 zombie result discarded")
        );
        rep.hangs_detected = 2;
        rep.zombies_reaped = 0;
        assert!(rep.render().contains(
            "watchdog: 2 hangs detected  0 zombie results discarded"
        ));
        // ordering sanity: reassembly's max-merge relies on it
        assert!(QualityLevel::Full < QualityLevel::Reduced);
        assert!(QualityLevel::Reduced < QualityLevel::Bilinear);
        assert_eq!(QualityLevel::Reduced.name(), "reduced");
        assert!(!QualityLevel::Full.is_degraded());
        assert!(QualityLevel::Bilinear.is_degraded());
    }

    #[test]
    fn worker_errors_render() {
        let records = vec![rec(0, 5)];
        let mut rep = PipelineReport::from_records(
            &records,
            Duration::from_secs(1),
            &names(&["int8", ""]),
            2,
            "whole-frame",
            vec![StreamMeta {
                offered: 3,
                ..meta(0, 8, 8, 3)
            }],
        );
        rep.errors.push("engine exploded after 1 frame".into());
        assert_eq!(rep.engine, "int8+?");
        assert_eq!(rep.incomplete, 2);
        let r = rep.render();
        assert!(r.contains("worker errors (1): engine exploded"));
        assert!(r.contains("2 incomplete"));
    }
}
