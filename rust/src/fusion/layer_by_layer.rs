//! Layer-by-layer baseline ([11]/[12] execution style): every layer's
//! output feature map round-trips through external DRAM.
//!
//! Output is exact (whole-frame SAME conv, no tiling loss); the cost is
//! the paper's motivating number — ~5 GB/s of DRAM traffic at FHD 60 fps
//! versus 0.41 GB/s for tilted fusion.
//!
//! §Microkernel: the whole-frame convs run the prepared row kernels on
//! the register-blocked strip microkernel, so even this baseline's
//! *compute* is the fast path — only its DRAM traffic model differs.

use crate::config::{AcceleratorConfig, FusionKind};
use crate::model::{PreparedModel, QuantModel, Scratch, Tensor};
use crate::reference::{
    self, conv3x3_final_prepared, conv3x3_relu_prepared,
};
use crate::sim::engine::{layer_cycles, EngineGeometry};
use crate::sim::RunStats;

use super::{base_frame_traffic, FrameResult, FusionScheduler};

/// No fusion: DRAM between every pair of layers.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerByLayerScheduler;

impl FusionScheduler for LayerByLayerScheduler {
    fn run_frame(
        &self,
        frame: &Tensor<u8>,
        qm: &QuantModel,
        cfg: &AcceleratorConfig,
    ) -> FrameResult {
        // prepared once per frame call; all layers share it
        let pm = PreparedModel::new(qm);
        let mut scratch = Scratch::new();
        let mut stats = RunStats::default();
        base_frame_traffic(frame, qm, &mut stats);
        let geo = EngineGeometry {
            pe_blocks: cfg.pe_blocks,
            macs_per_cycle: cfg.total_macs(),
        };

        let n = pm.n_layers();
        let mut h: Option<Tensor<u8>> = None;
        for (i, layer) in pm.layers.iter().enumerate() {
            let cost = layer_cycles(
                frame.h,
                frame.w,
                layer.cin,
                layer.cout,
                &geo,
            );
            stats.compute_cycles += cost.cycles;
            stats.mac_ops += cost.mac_ops;
            stats.mac_slots += cost.mac_slots;
            if i < n - 1 {
                let next = {
                    let input = h.as_ref().unwrap_or(frame);
                    conv3x3_relu_prepared(input, layer, &mut scratch)
                };
                // intermediate map: written to DRAM, read back next layer
                let bytes = next.byte_len() as u64;
                stats.dram_write_bytes += bytes;
                stats.dram_read_bytes += bytes;
                if let Some(old) = h.replace(next) {
                    scratch.recycle_u8(old);
                }
            }
        }
        let pre = {
            let input = h.as_ref().unwrap_or(frame);
            conv3x3_final_prepared(
                input,
                // PANIC: PreparedModel::new rejects empty models, so
                // there is always a last (final, non-ReLU) layer.
                pm.layers.last().unwrap(),
                &mut scratch,
            )
        };
        let hr = reference::add_anchor_and_shuffle(&pre, frame, pm.scale);
        // line buffers only: 3 input rows + weights resident
        stats.peak_pingpong_bytes =
            (3 * frame.w * pm.max_channels()) as u64;
        stats.tiles = 1;
        FrameResult { hr, stats }
    }

    fn kind(&self) -> FusionKind {
        FusionKind::LayerByLayer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::model::QuantModel;
    use crate::util::Xoshiro256pp;

    fn rand_frame(h: usize, w: usize, seed: u64) -> Tensor<u8> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut t = Tensor::new(h, w, 3);
        rng.fill_u8(&mut t.data);
        t
    }

    #[test]
    fn output_is_exact() {
        let qm = QuantModel::test_model(3, 3, 5, 3, 1);
        let frame = rand_frame(9, 11, 2);
        let res = LayerByLayerScheduler.run_frame(
            &frame,
            &qm,
            &AcceleratorConfig::paper(),
        );
        let want = reference::forward_int(&frame, &qm);
        assert_eq!(res.hr.data, want.data);
    }

    #[test]
    fn dram_traffic_includes_intermediates() {
        let qm = QuantModel::test_model(3, 3, 5, 3, 1);
        let frame = rand_frame(6, 8, 3);
        let res = LayerByLayerScheduler.run_frame(
            &frame,
            &qm,
            &AcceleratorConfig::paper(),
        );
        // two intermediate maps of 6*8*5 bytes, written + read
        let inter = 2 * 6 * 8 * 5;
        assert_eq!(
            res.stats.dram_write_bytes,
            (6 * 3 * 8 * 3 * 3 + inter) as u64
        );
        assert!(
            res.stats.dram_read_bytes
                > res.stats.dram_write_bytes / 2
        );
    }
}
