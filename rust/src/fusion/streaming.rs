//! Cache-resident streaming executor (§Streaming): full-width row-ring
//! layer fusion for the serving fast path.
//!
//! The paper's tilted schedule exists to keep fused intermediates in a
//! ~102 KB on-chip buffer; mapped onto a CPU, the same move is keeping
//! the fused working set in L2.  [`TiltedScheduler`] is deliberately
//! hardware-faithful — it re-stages every C-column tile of every layer
//! column-by-column through SRAM models and an [`OverlapQueue`] — so
//! the serving path paid a software analogue of the DRAM traffic the
//! chip eliminates.  [`StreamingScheduler`] restructures band execution
//! so activations stream through the minimal line buffers instead:
//!
//! * each layer keeps only a **3-row ring** of its input feature map
//!   (`Scratch::rings`, sized like the paper's eq. (1) line buffers:
//!   `3 x band_w x cout` bytes per layer) — map 0 and the residual
//!   anchor read the resident LR band directly, collapsing the
//!   eq. (2)/(3) buffers onto memory the caller already owns;
//! * as layer *k* retires band row *y*, layer *k+1* consumes it on the
//!   next step while it is hot in cache — the row-granular analogue of
//!   the tilt's "ready without waiting" diagonal (each layer lags its
//!   producer by exactly one row);
//! * the final conv produces one pre-residual row at a time
//!   (`Scratch::pre_row`) and the anchor-add + pixel-shuffle consumes
//!   it immediately ([`add_anchor_row_and_shuffle_into`]), so the
//!   whole-band i32 map never materializes;
//! * every conv runs [`conv_strip`] over **whole band-width rows** —
//!   the per-tile patch gather/scatter, the [`OverlapQueue`] payload
//!   copies and the per-tile-per-layer engine dispatch of the tilted
//!   path all disappear.
//!
//! Output is **bit-identical** to [`TiltedScheduler`] and to
//! [`reference::forward_int`] on the band (same zero-padded band
//! seams): the row schedule feeds [`conv_strip`] the exact
//! [`StripRows`] the SAME row driver would (rows outside the band are
//! `None`, horizontal padding is the strip's column mask), and integer
//! accumulation is order-identical.  `rust/tests/streaming_equivalence.rs`
//! pins all three against each other across randomized geometries,
//! scales, band heights, tile widths and kernel dispatches.
//!
//! A band run as a single full-height band has no seams at all, so
//! [`StreamingScheduler::run_whole_prepared`] is a drop-in,
//! bit-identical replacement for monolithic
//! [`reference::forward_int_prepared`] whose intermediate working set
//! is `O(layers x band_w)` rows instead of `O(layers x frame)` maps —
//! the default serving fast path of [`crate::coordinator::Int8Engine`].
//!
//! [`TiltedScheduler`]: super::TiltedScheduler
//! [`OverlapQueue`]: super::OverlapQueue
//! [`reference::forward_int`]: crate::reference::forward_int
//! [`reference::forward_int_prepared`]: crate::reference::forward_int_prepared
//! [`conv_strip`]: crate::reference::microkernel::conv_strip
//! [`StripRows`]: crate::reference::microkernel::StripRows
//! [`add_anchor_row_and_shuffle_into`]: crate::reference::add_anchor_row_and_shuffle_into

use crate::config::AcceleratorConfig;
use crate::model::{PreparedModel, QuantModel, Scratch, Tensor};
use crate::reference::add_anchor_row_and_shuffle_into;
use crate::reference::conv::{conv_row_strips, ConvOut};
use crate::reference::microkernel::{Isa, StripRows};
use crate::sim::RunStats;

use super::{run_frame_bands, FrameResult};

/// The row-ring fused band executor.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamingScheduler {
    /// Route every strip through the scalar kernel (the equivalence
    /// tests' dispatch override; mirrors the `force_scalar` knob of the
    /// `reference` conv entry points).
    pub force_scalar: bool,
}

impl StreamingScheduler {
    /// Run one band with zero-padded seams — bit-identical to
    /// [`super::TiltedScheduler::run_band_prepared`] and to
    /// [`crate::reference::forward_int`] on the band.
    ///
    /// The HR band's storage comes from the scratch pool; recycle it
    /// with [`Scratch::recycle_u8`] to stay allocation-free.  Stats
    /// cover the functional path only (MAC ops): the streaming
    /// executor has no SRAM/cycle model — that is the tilted
    /// scheduler's job — and every memory-model field stays zero.
    pub fn run_band_prepared(
        &self,
        band: &Tensor<u8>,
        pm: &PreparedModel,
        scratch: &mut Scratch,
    ) -> (Tensor<u8>, RunStats) {
        let rows = band.h;
        let w = band.w;
        let c0 = pm.in_channels();
        assert_eq!(band.c, c0, "streaming executor: cin mismatch");
        assert!(rows > 0 && w > 0, "streaming executor: empty band");
        let n_layers = pm.n_layers();
        let scale = pm.scale;
        let isa = Isa::select(self.force_scalar);

        // -- line buffers: a 3-row ring per intermediate map ----------
        // rings[m] caches map m+1 (the output of layer m+1) for maps
        // 1 ..= L-1; ring slot = row % 3.  A row is written whole
        // before any consumer reads it, so no zeroing between bands.
        scratch.rings.resize(n_layers.saturating_sub(1), Vec::new());
        for (m, ring) in scratch.rings.iter_mut().enumerate() {
            ring.resize(3 * w * pm.layers[m].cout, 0);
        }
        let last = &pm.layers[n_layers - 1];
        scratch.pre_row.resize(w * last.cout, 0);

        let mut stats = RunStats::default();
        let mut hr_band = scratch.take_u8(rows * scale, w * scale, c0);

        // -- the row pipeline: step r ingests band row r (implicitly —
        // the band is resident) and layer k retires its row r - k -----
        for r in 0..rows + n_layers {
            // §Watchdog: a zombified worker observes cancellation at
            // row-retirement granularity and aborts the doomed band —
            // the partial result is discarded by the caller's
            // generation check, never delivered.
            if scratch.cancel.as_ref().is_some_and(|t| t.is_cancelled()) {
                break;
            }
            for k in 1..=n_layers {
                let y = r as isize - k as isize;
                if y < 0 || y >= rows as isize {
                    continue;
                }
                let y = y as usize;
                let layer = &pm.layers[k - 1];
                let in_bytes = w * layer.cin;
                // map k-1's rows y-1 ..= y+1; rows outside the band are
                // None (the zero-padded band seam), exactly like the
                // SAME row driver on the band
                let (src_rings, dst_rings) =
                    scratch.rings.split_at_mut(k - 1);
                let src_ring: Option<&[u8]> = if k >= 2 {
                    Some(src_rings[k - 2].as_slice())
                } else {
                    None // layer 1 reads the resident band directly
                };
                let strip_rows = StripRows {
                    rows: [
                        input_row(band, src_ring, in_bytes, rows, y as isize - 1),
                        input_row(band, src_ring, in_bytes, rows, y as isize),
                        input_row(band, src_ring, in_bytes, rows, y as isize + 1),
                    ],
                    col_lo: 0,
                    col_hi: w as isize,
                };
                if k < n_layers {
                    // ReLU layer: retire row y straight into layer
                    // k+1's ring, hot for the next step
                    let out_bytes = w * layer.cout;
                    let dst = &mut dst_rings[0]
                        [(y % 3) * out_bytes..][..out_bytes];
                    let mut out = ConvOut::Relu(dst);
                    conv_row_strips(
                        &strip_rows, layer, w, 0, isa, &mut out,
                    );
                } else {
                    // final conv: one pre-residual row, fused with the
                    // anchor add + pixel shuffle (the anchor is the
                    // resident band row itself — the L-row lag of the
                    // paper's eq. (3) ring costs nothing in software)
                    let pre = &mut scratch.pre_row[..w * layer.cout];
                    {
                        let mut out = ConvOut::Final(&mut *pre);
                        conv_row_strips(
                            &strip_rows, layer, w, 0, isa, &mut out,
                        );
                    }
                    let anchor = &band.data[y * w * c0..][..w * c0];
                    add_anchor_row_and_shuffle_into(
                        pre, anchor, scale, c0, y, &mut hr_band,
                    );
                }
            }
        }

        // functional-path accounting: useful MACs only.  Every
        // memory-model field — including `tiles`, whose unit is the
        // tilted scheduler's C-column tiles — stays zero, so merged
        // reports never mix units across executors.
        for layer in &pm.layers {
            stats.mac_ops +=
                9 * rows as u64 * w as u64 * layer.cin as u64
                    * layer.cout as u64;
        }
        (hr_band, stats)
    }

    /// Frame-level prepared path: bands of `cfg.tile_rows` rows with
    /// zero-padded seams — bit-identical to
    /// [`super::TiltedScheduler::run_frame_prepared`].
    pub fn run_frame_prepared(
        &self,
        frame: &Tensor<u8>,
        pm: &PreparedModel,
        cfg: &AcceleratorConfig,
        scratch: &mut Scratch,
    ) -> FrameResult {
        run_frame_bands(
            frame,
            pm,
            cfg.tile_rows,
            scratch,
            |band, scratch| self.run_band_prepared(band, pm, scratch),
        )
    }

    /// Whole-input single-band execution: no seams, bit-identical to
    /// monolithic [`crate::reference::forward_int_prepared`] — the
    /// serving fast path of [`crate::coordinator::Int8Engine`] under
    /// the `streaming` executor.
    pub fn run_whole_prepared(
        &self,
        frame: &Tensor<u8>,
        pm: &PreparedModel,
        scratch: &mut Scratch,
    ) -> Tensor<u8> {
        self.run_band_prepared(frame, pm, scratch).0
    }

    /// One-shot wrapper: packs the model and allocates scratch per
    /// call (tests / single images).
    pub fn run_band(
        &self,
        band: &Tensor<u8>,
        qm: &QuantModel,
    ) -> (Tensor<u8>, RunStats) {
        let pm = PreparedModel::new(qm);
        let mut scratch = Scratch::new();
        self.run_band_prepared(band, &pm, &mut scratch)
    }
}

/// Row `yy` of the current layer's input map: `None` outside the band
/// (the zero-padded seam), the ring slot `yy % 3` when the input is an
/// intermediate map, or the resident band row itself for map 0.
#[inline(always)]
fn input_row<'a>(
    band: &'a Tensor<u8>,
    src_ring: Option<&'a [u8]>,
    in_bytes: usize,
    rows: usize,
    yy: isize,
) -> Option<&'a [u8]> {
    if yy < 0 || yy >= rows as isize {
        return None;
    }
    let yy = yy as usize;
    Some(match src_ring {
        None => &band.data[yy * in_bytes..][..in_bytes],
        Some(ring) => &ring[(yy % 3) * in_bytes..][..in_bytes],
    })
}

#[cfg(test)]
mod tests {
    use super::super::TiltedScheduler;
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::reference;
    use crate::util::Xoshiro256pp;

    fn rand_frame(h: usize, w: usize, c: usize, seed: u64) -> Tensor<u8> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut t = Tensor::new(h, w, c);
        rng.fill_u8(&mut t.data);
        t
    }

    #[test]
    fn band_matches_reference_exactly() {
        let qm = QuantModel::test_model(3, 3, 5, 3, 21);
        let band = rand_frame(6, 24, 3, 1);
        let (hr, _) = StreamingScheduler::default().run_band(&band, &qm);
        let want = reference::forward_int(&band, &qm);
        assert_eq!(hr.data, want.data, "streaming band differs from reference");
    }

    #[test]
    fn band_matches_tilted_exactly() {
        let qm = QuantModel::test_model(4, 3, 6, 3, 5);
        let band = rand_frame(7, 19, 3, 9);
        let cfg = AcceleratorConfig {
            tile_rows: 7,
            tile_cols: 4,
            ..AcceleratorConfig::paper()
        };
        let (s, _) = StreamingScheduler::default().run_band(&band, &qm);
        let (t, _) = TiltedScheduler::default().run_band(&band, &qm, &cfg);
        assert_eq!(s.data, t.data);
    }

    #[test]
    fn degenerate_geometries_match_reference() {
        // 1-row band, 1-col band, single-layer model
        for (layers, h, w, seed) in
            [(2, 1, 9, 3), (2, 6, 1, 4), (1, 4, 5, 5), (3, 2, 2, 6)]
        {
            let qm = QuantModel::test_model(layers, 3, 4, 2, seed);
            let band = rand_frame(h, w, 3, seed);
            let (hr, _) = StreamingScheduler::default().run_band(&band, &qm);
            let want = reference::forward_int(&band, &qm);
            assert_eq!(hr.data, want.data, "{layers} layers, {h}x{w}");
        }
    }

    #[test]
    fn force_scalar_is_bit_identical() {
        let qm = QuantModel::test_model(3, 3, 5, 3, 11);
        let band = rand_frame(5, 13, 3, 2);
        let (a, _) = StreamingScheduler::default().run_band(&band, &qm);
        let (b, _) = StreamingScheduler { force_scalar: true }
            .run_band(&band, &qm);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn scratch_reuse_across_heterogeneous_bands() {
        // one Scratch serving bands of different geometry must match
        // the one-shot wrapper bit for bit (stale ring content must
        // never leak into a later band)
        let qm = QuantModel::test_model(3, 3, 5, 3, 33);
        let pm = PreparedModel::new(&qm);
        let mut scratch = Scratch::new();
        let sched = StreamingScheduler::default();
        for (h, w, seed) in [(6, 17, 40), (3, 23, 41), (8, 9, 42)] {
            let band = rand_frame(h, w, 3, seed);
            let (a, _) = sched.run_band_prepared(&band, &pm, &mut scratch);
            let (b, _) = sched.run_band(&band, &qm);
            assert_eq!(a.data, b.data, "band {h}x{w}");
            scratch.recycle_u8(a);
        }
    }

    #[test]
    fn frame_matches_tilted_frame() {
        let qm = QuantModel::test_model(2, 3, 4, 3, 13);
        let frame = rand_frame(13, 16, 3, 3);
        let cfg = AcceleratorConfig {
            tile_rows: 6,
            tile_cols: 4,
            ..AcceleratorConfig::paper()
        };
        let pm = PreparedModel::new(&qm);
        let mut scratch = Scratch::new();
        let s = StreamingScheduler::default().run_frame_prepared(
            &frame,
            &pm,
            &cfg,
            &mut scratch,
        );
        let t = TiltedScheduler::default().run_frame(&frame, &qm, &cfg);
        assert_eq!(s.hr.data, t.hr.data);
        // frame-level DRAM base accounting matches the schedulers'
        assert_eq!(s.stats.dram_read_bytes, t.stats.dram_read_bytes);
        assert_eq!(s.stats.dram_write_bytes, t.stats.dram_write_bytes);
    }

    #[test]
    fn whole_frame_single_band_matches_monolithic() {
        let qm = QuantModel::test_model(3, 3, 6, 3, 7);
        let frame = rand_frame(11, 14, 3, 8);
        let pm = PreparedModel::new(&qm);
        let mut scratch = Scratch::new();
        let got = StreamingScheduler::default().run_whole_prepared(
            &frame,
            &pm,
            &mut scratch,
        );
        let want = reference::forward_int(&frame, &qm);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn cancelled_scratch_aborts_the_band_early() {
        let qm = QuantModel::test_model(3, 3, 5, 3, 21);
        let band = rand_frame(6, 24, 3, 1);
        let pm = PreparedModel::new(&qm);
        let mut scratch = Scratch::new();
        let sched = StreamingScheduler::default();
        // an uncancelled token changes nothing: bit-identical output
        let tok = crate::util::cancel::CancelToken::new();
        scratch.cancel = Some(tok.clone());
        let (hr, _) = sched.run_band_prepared(&band, &pm, &mut scratch);
        let want = reference::forward_int(&band, &qm);
        assert_eq!(hr.data, want.data);
        scratch.recycle_u8(hr);
        // a pre-cancelled token aborts before any row retires
        tok.cancel();
        let (hr, _) = sched.run_band_prepared(&band, &pm, &mut scratch);
        assert!(hr.data.iter().all(|&b| b == 0), "aborted band is blank");
    }

    #[test]
    fn stats_count_macs_only() {
        let qm = QuantModel::test_model(2, 3, 4, 2, 1);
        let band = rand_frame(5, 8, 3, 1);
        let (_, stats) = StreamingScheduler::default().run_band(&band, &qm);
        let want: u64 = qm
            .layers
            .iter()
            .map(|l| 9 * 5 * 8 * l.cin as u64 * l.cout as u64)
            .sum();
        assert_eq!(stats.mac_ops, want);
        // no memory model on the streaming path — and `tiles` stays 0
        // too (its unit is the tilted scheduler's C-column tiles)
        assert_eq!(stats.tiles, 0);
        assert_eq!(stats.sram_reads, 0);
        assert_eq!(stats.compute_cycles, 0);
    }
}
