//! Classical fused-layer baseline (Alwani et al. [14], recompute
//! variant): rectangular tiles, all layers fused, exactness preserved by
//! an `L`-pixel input halo per side that is re-loaded from DRAM and
//! re-computed layer by layer.
//!
//! This is the design point the paper's Table II compares against with a
//! 60x60 tile: intermediate maps stay on chip (like tilted fusion) but
//! the ping-pong buffers must hold the full halo'd tile, and the halo
//! MACs/bytes are pure overhead that grows as tiles shrink — the reason
//! classical fusion cannot use an 8-wide tile.
//!
//! §Microkernel: the fused conv chain runs the prepared patch kernels,
//! i.e. the register-blocked strip microkernel with its fused requant
//! epilogue — the halo'd tiles here are just wider patches.

use crate::config::{AcceleratorConfig, FusionKind};
use crate::model::{PreparedModel, QuantModel, Scratch, Tensor};
use crate::reference::{
    add_anchor_and_shuffle_into, conv_patch_final_prepared,
    conv_patch_relu_prepared,
};
use crate::sim::engine::{layer_cycles, EngineGeometry};
use crate::sim::RunStats;

use super::{base_frame_traffic, FrameResult, FusionScheduler};

/// Rectangular fused tiles with recompute halos.
#[derive(Clone, Copy, Debug)]
pub struct ClassicalScheduler {
    /// Square-ish tile geometry; the paper's comparison uses 60x60.
    pub tile_rows: usize,
    pub tile_cols: usize,
}

impl Default for ClassicalScheduler {
    fn default() -> Self {
        Self {
            tile_rows: 60,
            tile_cols: 60,
        }
    }
}

impl FusionScheduler for ClassicalScheduler {
    fn run_frame(
        &self,
        frame: &Tensor<u8>,
        qm: &QuantModel,
        cfg: &AcceleratorConfig,
    ) -> FrameResult {
        // prepared once per frame call; every tile shares it
        let pm = PreparedModel::new(qm);
        let mut scratch = Scratch::new();
        let mut stats = RunStats::default();
        base_frame_traffic(frame, qm, &mut stats);
        let geo = EngineGeometry {
            pe_blocks: cfg.pe_blocks,
            macs_per_cycle: cfg.total_macs(),
        };
        let n = pm.n_layers();
        let halo = n; // one pixel per fused layer per side
        let scale = pm.scale;
        let mut hr: Tensor<u8> =
            Tensor::new(frame.h * scale, frame.w * scale, frame.c);
        let mut peak_ping: u64 = 0;

        let mut ty = 0;
        while ty < frame.h {
            let th = self.tile_rows.min(frame.h - ty);
            let mut tx = 0;
            while tx < frame.w {
                let tw = self.tile_cols.min(frame.w - tx);
                stats.tiles += 1;

                // --- assemble the halo'd input tile (zero outside) ---
                let ph = th + 2 * halo;
                let pw = tw + 2 * halo;
                let mut cur = scratch.take_u8(ph, pw, frame.c);
                let mut halo_extra_bytes = 0u64;
                for y in 0..ph {
                    for x in 0..pw {
                        let sy = ty as isize + y as isize - halo as isize;
                        let sx = tx as isize + x as isize - halo as isize;
                        if sy >= 0
                            && sy < frame.h as isize
                            && sx >= 0
                            && sx < frame.w as isize
                        {
                            for c in 0..frame.c {
                                cur.set(
                                    y,
                                    x,
                                    c,
                                    frame.get(sy as usize, sx as usize, c),
                                );
                            }
                            let in_core = sy >= ty as isize
                                && sy < (ty + th) as isize
                                && sx >= tx as isize
                                && sx < (tx + tw) as isize;
                            if !in_core {
                                halo_extra_bytes += frame.c as u64;
                            }
                        }
                    }
                }
                // halo pixels are *re-read* from DRAM (the core pixels
                // are already counted by base_frame_traffic)
                stats.dram_read_bytes += halo_extra_bytes;

                // --- fused conv chain, shrinking by 2 per layer -------
                // Exactness requires re-zeroing outside the image after
                // each layer (SAME-pad semantics), same as the Pallas
                // fused-band kernel.
                let mut region_y = ty as isize - halo as isize + 1;
                let mut region_x = tx as isize - halo as isize + 1;
                let mut pre: Option<Tensor<i32>> = None;
                for (i, layer) in pm.layers.iter().enumerate() {
                    let orows = cur.h - 2;
                    let ocols = cur.w - 2;
                    let cost = layer_cycles(
                        orows,
                        ocols,
                        layer.cin,
                        layer.cout,
                        &geo,
                    );
                    stats.compute_cycles +=
                        cost.cycles + cfg.buffer_swap_cycles;
                    stats.mac_ops += cost.mac_ops;
                    stats.mac_slots += cost.mac_slots
                        + cfg.buffer_swap_cycles * cfg.total_macs() as u64;
                    peak_ping = peak_ping.max(
                        (cur.h * cur.w * layer.cin
                            + orows * ocols * layer.cout)
                            as u64,
                    );
                    if i < n - 1 {
                        let mut next =
                            conv_patch_relu_prepared(&cur, layer, &mut scratch);
                        zero_outside(
                            &mut next,
                            region_y,
                            region_x,
                            frame.h,
                            frame.w,
                        );
                        scratch.recycle_u8(std::mem::replace(&mut cur, next));
                        region_y += 1;
                        region_x += 1;
                    } else {
                        pre = Some(conv_patch_final_prepared(
                            &cur,
                            layer,
                            &mut scratch,
                        ));
                    }
                }
                scratch.recycle_u8(cur);
                // PANIC: PreparedModel::new rejects empty models, so
                // the per-layer loop above ran at least once and the
                // final iteration always assigns `pre`.
                let pre = pre.unwrap();
                // core region of the final map = [halo-?]: after n
                // layers the map shrank by n per side relative to the
                // halo'd input; its top-left is at image (ty, tx).
                debug_assert_eq!(pre.h, th + 2 * halo - 2 * n + 2 * 0);
                let mut core = scratch.take_i32(th, tw, pre.c);
                for y in 0..th {
                    for x in 0..tw {
                        for c in 0..pre.c {
                            core.set(y, x, c, pre.get(y, x, c));
                        }
                    }
                }
                scratch.recycle_i32(pre);
                let mut anchor = scratch.take_u8(th, tw, frame.c);
                for y in 0..th {
                    for x in 0..tw {
                        for c in 0..frame.c {
                            anchor.set(y, x, c, frame.get(ty + y, tx + x, c));
                        }
                    }
                }
                let mut hr_tile =
                    scratch.take_u8(th * scale, tw * scale, frame.c);
                add_anchor_and_shuffle_into(&core, &anchor, scale, &mut hr_tile);
                let row_bytes = hr_tile.w * frame.c;
                for y in 0..hr_tile.h {
                    let src = y * row_bytes;
                    let dst = hr.idx(ty * scale + y, tx * scale, 0);
                    hr.data[dst..dst + row_bytes].copy_from_slice(
                        &hr_tile.data[src..src + row_bytes],
                    );
                }
                scratch.recycle_i32(core);
                scratch.recycle_u8(anchor);
                scratch.recycle_u8(hr_tile);
                tx += self.tile_cols;
            }
            ty += self.tile_rows;
        }
        // ping-pong pair must hold the largest in/out maps concurrently
        stats.peak_pingpong_bytes = peak_ping;
        stats.tiles = stats.tiles.max(1);
        FrameResult { hr, stats }
    }

    fn kind(&self) -> FusionKind {
        FusionKind::Classical
    }
}

/// Zero every element whose image coordinate falls outside the frame —
/// restores SAME zero-padding semantics between fused layers.
fn zero_outside(
    t: &mut Tensor<u8>,
    y0: isize,
    x0: isize,
    img_h: usize,
    img_w: usize,
) {
    for y in 0..t.h {
        let gy = y0 + y as isize;
        for x in 0..t.w {
            let gx = x0 + x as isize;
            if gy < 0
                || gy >= img_h as isize
                || gx < 0
                || gx >= img_w as isize
            {
                for c in 0..t.c {
                    t.set(y, x, c, 0);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::model::QuantModel;
    use crate::reference;
    use crate::util::Xoshiro256pp;

    fn rand_frame(h: usize, w: usize, seed: u64) -> Tensor<u8> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut t = Tensor::new(h, w, 3);
        rng.fill_u8(&mut t.data);
        t
    }

    #[test]
    fn recompute_halos_preserve_exactness() {
        let qm = QuantModel::test_model(3, 3, 5, 3, 31);
        let frame = rand_frame(13, 17, 4);
        let sched = ClassicalScheduler {
            tile_rows: 6,
            tile_cols: 7,
        };
        let res =
            sched.run_frame(&frame, &qm, &AcceleratorConfig::paper());
        let want = reference::forward_int(&frame, &qm);
        assert_eq!(res.hr.data, want.data);
    }

    #[test]
    fn halo_recompute_costs_macs() {
        let qm = QuantModel::test_model(3, 3, 5, 3, 31);
        let frame = rand_frame(12, 12, 5);
        let small = ClassicalScheduler {
            tile_rows: 4,
            tile_cols: 4,
        }
        .run_frame(&frame, &qm, &AcceleratorConfig::paper());
        let big = ClassicalScheduler {
            tile_rows: 12,
            tile_cols: 12,
        }
        .run_frame(&frame, &qm, &AcceleratorConfig::paper());
        // 4x4 tiles with a 3-layer halo pay ~28 % extra MACs on this
        // small frame; the paper-scale ratio is exercised in the
        // fig1/ablation benches
        assert!(
            small.stats.mac_ops as f64 > 1.2 * big.stats.mac_ops as f64,
            "small tiles must pay recompute: {} vs {}",
            small.stats.mac_ops,
            big.stats.mac_ops
        );
        assert_eq!(small.hr.data, big.hr.data);
    }
}
