//! The overlap buffer: a queue-style addressed SRAM holding the two
//! rightmost columns of each in-flight feature map (Section III.F).
//!
//! Entries are labelled `(tile, map)` so the scheduler's discipline —
//! conv *k* of tile *t* consumes the front entry, which must be
//! `(t-1, k-1)` — is asserted, not assumed.  Capacity is
//! `(n_layers + 2)` entries of `rows * 2 * max_ch` bytes, the paper's
//! eq. (2); the steady-state occupancy of L+1 proves the +2 is exactly
//! the pipeline slack the paper provisions.

use crate::sim::Sram;

/// Label of a queue entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EntryLabel {
    pub tile: usize,
    /// Feature-map index: 0 = the LR input, k = output of conv k.
    pub map: usize,
}

/// Queue-addressed overlap SRAM.
pub struct OverlapQueue {
    sram: Sram,
    /// Per-slot payload byte length and label.
    labels: Vec<Option<(EntryLabel, usize)>>,
    entry_bytes: usize,
    front: usize,
    count: usize,
    max_count: usize,
}

impl OverlapQueue {
    /// `depth` entries of `entry_bytes` each (rows * 2 * max_ch).
    pub fn new(depth: usize, entry_bytes: usize) -> Self {
        Self {
            sram: Sram::new("overlap", depth * entry_bytes),
            labels: vec![None; depth],
            entry_bytes,
            front: 0,
            count: 0,
            max_count: 0,
        }
    }

    pub fn depth(&self) -> usize {
        self.labels.len()
    }

    pub fn capacity_bytes(&self) -> usize {
        self.sram.capacity()
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Highest simultaneous occupancy observed.
    pub fn max_count(&self) -> usize {
        self.max_count
    }

    pub fn sram(&self) -> &Sram {
        &self.sram
    }

    /// Push the two rightmost columns of `label`'s feature map.
    pub fn push_back(&mut self, label: EntryLabel, payload: &[u8]) {
        assert!(
            payload.len() <= self.entry_bytes,
            "overlap entry too large: {} > {}",
            payload.len(),
            self.entry_bytes
        );
        assert!(
            self.count < self.depth(),
            "overlap queue overflow (depth {}) pushing {:?}",
            self.depth(),
            label
        );
        let slot = (self.front + self.count) % self.depth();
        self.sram.write(slot * self.entry_bytes, payload);
        self.labels[slot] = Some((label, payload.len()));
        self.count += 1;
        self.max_count = self.max_count.max(self.count);
    }

    /// Label at the queue front, if any.
    pub fn front_label(&self) -> Option<EntryLabel> {
        self.labels[self.front].map(|(l, _)| l)
    }

    /// Read the front payload, asserting it carries `expect`.
    pub fn read_front(&self, expect: EntryLabel) -> Vec<u8> {
        let mut out = Vec::new();
        self.read_front_into(expect, &mut out);
        out
    }

    /// [`OverlapQueue::read_front`] into a reusable buffer (cleared
    /// first) — the zero-allocation variant of the tilted band loop.
    pub fn read_front_into(&self, expect: EntryLabel, out: &mut Vec<u8>) {
        let Some((label, len)) = self.labels[self.front] else {
            // PANIC: an empty front slot means the tilt schedule
            // consumed an overlap entry it never produced — a
            // scheduler bug, which must fail loudly rather than
            // serve stale SRAM contents.
            panic!("overlap queue empty reading {expect:?}");
        };
        assert_eq!(
            label, expect,
            "overlap queue out of order: front {label:?}, expected {expect:?}"
        );
        out.clear();
        out.extend_from_slice(
            self.sram.read(self.front * self.entry_bytes, len),
        );
    }

    /// Pop the front entry (it must carry `expect`).
    pub fn pop_front(&mut self, expect: EntryLabel) {
        let Some((label, _)) = self.labels[self.front] else {
            // PANIC: popping an empty slot is the same
            // schedule-integrity violation as in `read_front_into`.
            panic!("overlap queue empty popping {expect:?}");
        };
        assert_eq!(label, expect, "overlap pop out of order");
        self.labels[self.front] = None;
        self.front = (self.front + 1) % self.depth();
        self.count -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lbl(tile: usize, map: usize) -> EntryLabel {
        EntryLabel { tile, map }
    }

    #[test]
    fn fifo_order_with_labels() {
        let mut q = OverlapQueue::new(4, 8);
        q.push_back(lbl(0, 0), &[1; 8]);
        q.push_back(lbl(0, 1), &[2; 8]);
        assert_eq!(q.read_front(lbl(0, 0)), vec![1; 8]);
        q.pop_front(lbl(0, 0));
        assert_eq!(q.front_label(), Some(lbl(0, 1)));
        q.pop_front(lbl(0, 1));
        assert_eq!(q.count(), 0);
    }

    #[test]
    fn ring_wraps() {
        let mut q = OverlapQueue::new(3, 4);
        for i in 0..10 {
            q.push_back(lbl(i, 0), &[i as u8; 4]);
            if i >= 1 {
                q.pop_front(lbl(i - 1, 0));
            }
        }
        assert_eq!(q.read_front(lbl(9, 0)), vec![9; 4]);
        assert_eq!(q.max_count(), 2);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut q = OverlapQueue::new(2, 4);
        q.push_back(lbl(0, 0), &[0; 4]);
        q.push_back(lbl(0, 1), &[0; 4]);
        q.push_back(lbl(0, 2), &[0; 4]);
    }

    #[test]
    #[should_panic(expected = "out of order")]
    fn wrong_label_read_panics() {
        let mut q = OverlapQueue::new(2, 4);
        q.push_back(lbl(3, 1), &[0; 4]);
        q.read_front(lbl(3, 2));
    }

    #[test]
    fn short_payload_allowed() {
        // clamped tiles push fewer bytes (narrow maps at image edges)
        let mut q = OverlapQueue::new(2, 8);
        q.push_back(lbl(0, 0), &[5; 4]);
        assert_eq!(q.read_front(lbl(0, 0)), vec![5; 4]);
    }

    #[test]
    fn scheduler_discipline_steady_state_occupancy() {
        // Reproduce the tilted schedule's queue discipline for L maps
        // over T tiles: tile t pushes (t, 0..L-1) going down the layer
        // stack, and conv k of tile t pops (t-1, k-1) first.  Steady
        // state must hold exactly L+1 entries (capacity L+2, eq. (2)).
        let l = 4; // maps 0..=3 queued (final map never queued)
        let mut q = OverlapQueue::new(l + 2, 8);
        for t in 0..6usize {
            // entering tile t: push map 0, then for each conv k pop the
            // previous tile's map k-1 and push this tile's map k
            q.push_back(lbl(t, 0), &[t as u8; 8]);
            for k in 1..l {
                if t >= 1 {
                    assert_eq!(q.front_label(), Some(lbl(t - 1, k - 1)));
                    q.pop_front(lbl(t - 1, k - 1));
                }
                q.push_back(lbl(t, k), &[(10 * t + k) as u8; 8]);
            }
            if t >= 1 {
                q.pop_front(lbl(t - 1, l - 1));
            }
        }
        assert_eq!(q.count(), l, "one full tile of maps resident");
        assert!(
            q.max_count() <= l + 1,
            "steady-state occupancy {} exceeded L+1",
            q.max_count()
        );
    }

    #[test]
    fn seam_payloads_round_trip_column_bytes() {
        // the payload is the two rightmost columns; bytes must come
        // back verbatim through the SRAM (seam correctness depends on
        // this, not just on labels)
        let mut q = OverlapQueue::new(3, 12);
        let col_a = [1u8, 2, 3, 4, 5, 6];
        let col_b = [7u8, 8, 9, 10, 11, 12];
        let mut payload = col_a.to_vec();
        payload.extend_from_slice(&col_b);
        q.push_back(lbl(2, 1), &payload);
        let back = q.read_front(lbl(2, 1));
        assert_eq!(&back[..6], &col_a);
        assert_eq!(&back[6..], &col_b);
    }

    #[test]
    #[should_panic(expected = "out of order")]
    fn pop_with_stale_tile_label_panics() {
        // popping tile t's entry while t-1's is still at the front is
        // the classic seam bug; the queue must catch it
        let mut q = OverlapQueue::new(4, 4);
        q.push_back(lbl(0, 0), &[0; 4]);
        q.push_back(lbl(1, 0), &[1; 4]);
        q.pop_front(lbl(1, 0));
    }
}
