//! Tilted layer fusion (Section II) against real memory models.
//!
//! A band of `R` rows is processed in parallelepiped tiles of `C`
//! columns: the region of feature map *k* (output of conv *k-1*, map 0 =
//! the LR input) for tile *t* is columns `[tC - k, (t+1)C - 1 - k]` —
//! each deeper layer shifts one pixel left (Fig. 2).  Consequences,
//! all modelled here explicitly:
//!
//! * the right boundary of conv *k*'s input (column `hi+1`) is exactly
//!   the last column conv *k-1* just produced in this tile — "ready
//!   without waiting" (the red pixels of Fig. 2);
//! * the left boundary (columns `lo-1`, `lo`) is the previous tile's two
//!   rightmost columns of map *k-1*, read from the queue-addressed
//!   [`OverlapQueue`] (the blue pixels);
//! * the residual anchor of the final layer lags `L` columns behind the
//!   input stream, so the residual ring holds `C + L` input columns —
//!   the paper's eq. (3);
//! * vertical band seams are zero-padded: the only information loss.
//!
//! The band output is bit-identical to monolithic band inference
//! (`reference::forward_int` on the band) — asserted by
//! `rust/tests/fusion_exactness.rs`.
//!
//! §Perf: [`TiltedScheduler::run_band_prepared`] is the steady-state
//! serving path — weights arrive packed in a [`PreparedModel`] (once
//! per model/worker, not per call) and all tile-loop working memory
//! (patches, column/payload staging, engine outputs) is borrowed from
//! a per-worker [`Scratch`], so the band loop performs **no heap
//! allocation per tile**.  The unprepared [`TiltedScheduler::run_band`]
//! wrapper packs on the fly for tests and one-shot callers.
//!
//! §Microkernel: each tile conv the engine runs
//! ([`crate::reference::conv_patch_relu_prepared`] /
//! `conv_patch_final_prepared`) executes on the register-blocked strip
//! microkernel — strips of `MK_P` output pixels with the requant
//! epilogue fused into the register tile — so the steady-state band
//! loop is both allocation-free *and* amortizes every weight fetch
//! over a pixel strip, the software analogue of the paper's MAC-array
//! weight reuse.

use crate::config::{AcceleratorConfig, FidelityKind, FusionKind};
use crate::model::{PreparedModel, QuantModel, Scratch, Tensor};
use crate::reference::add_anchor_and_shuffle_into;
use crate::sim::engine::{
    AnalyticEngine, AnyTileEngine, CycleExactEngine, LayerOut, TileEngine,
};
use crate::sim::{RunStats, Sram};

use super::overlap::{EntryLabel, OverlapQueue};
use super::{
    band_of, band_ranges, run_frame_bands, FrameResult,
    FusionScheduler,
};

/// The paper's scheduler.
#[derive(Clone, Copy, Debug)]
pub struct TiltedScheduler {
    pub fidelity: FidelityKind,
}

impl Default for TiltedScheduler {
    fn default() -> Self {
        Self {
            fidelity: FidelityKind::Analytic,
        }
    }
}

impl TiltedScheduler {
    pub fn cycle_exact() -> Self {
        Self {
            fidelity: FidelityKind::CycleExact,
        }
    }

    /// The fidelity's engine as a `Copy` enum (§Perf): constructing it
    /// is free — no per-band heap allocation — and `run_layer` calls
    /// dispatch statically through a match instead of a vtable, for
    /// every tile-layer of every band.
    fn engine(&self) -> AnyTileEngine {
        match self.fidelity {
            FidelityKind::Analytic => {
                AnyTileEngine::Analytic(AnalyticEngine::paper())
            }
            FidelityKind::CycleExact => {
                AnyTileEngine::CycleExact(CycleExactEngine::paper())
            }
        }
    }

    /// Run one band; returns the HR band and its stats.  One-shot
    /// wrapper: packs the model and allocates scratch per call.
    pub fn run_band(
        &self,
        band: &Tensor<u8>,
        qm: &QuantModel,
        cfg: &AcceleratorConfig,
    ) -> (Tensor<u8>, RunStats) {
        let pm = PreparedModel::new(qm);
        let mut scratch = Scratch::new();
        self.run_band_prepared(band, &pm, cfg, &mut scratch)
    }

    /// Run one band over prepared weights and a reusable scratch arena —
    /// the steady-state serving path (§Perf).
    pub fn run_band_prepared(
        &self,
        band: &Tensor<u8>,
        pm: &PreparedModel,
        cfg: &AcceleratorConfig,
        scratch: &mut Scratch,
    ) -> (Tensor<u8>, RunStats) {
        let engine = self.engine();
        let rows = band.h;
        let width = band.w;
        let c_tile = cfg.tile_cols.max(2); // sliding-2 window needs C >= 2
        let n_layers = pm.n_layers();
        let max_ch = pm.max_channels();
        let ch0 = pm.in_channels();
        let scale = pm.scale;

        // --- on-chip memories, provisioned per eqs. (1)-(3) -----------
        let col_stride = cfg.tile_rows * max_ch; // bytes per buffered column
        let mut ping = [
            Sram::new("ping_a", cfg.tile_rows * c_tile * max_ch),
            Sram::new("ping_b", cfg.tile_rows * c_tile * max_ch),
        ];
        let mut queue = OverlapQueue::new(
            n_layers + 2,
            cfg.tile_rows * 2 * max_ch,
        );
        let res_cols = c_tile + n_layers;
        let mut residual =
            Sram::new("residual", ch0 * cfg.tile_rows * res_cols);

        // Functional bookkeeping of what each queue entry contains
        // (image-space column indices); the authoritative bytes live in
        // the queue SRAM and are read back through it.  The schedule
        // only ever holds entries of tiles t-1 and t, so two per-map
        // slots replace a hash map: `prev_cols[k]` = the two columns of
        // map k pushed during tile t-1, `cur_cols[k]` during tile t.
        let mut prev_cols: Vec<Option<(usize, usize)>> =
            vec![None; n_layers + 1];
        let mut cur_cols: Vec<Option<(usize, usize)>> =
            vec![None; n_layers + 1];

        let mut stats = RunStats::default();
        let mut hr_band: Tensor<u8> =
            Tensor::new(rows * scale, width * scale, ch0);

        let n_tiles = width.div_ceil(c_tile);
        let region =
            |t: usize, k: usize| -> Option<(usize, usize)> {
                let lo = (t * c_tile) as isize - k as isize;
                let hi = ((t + 1) * c_tile) as isize - 1 - k as isize;
                let lo_c = lo.max(0) as usize;
                let hi_c = hi.min(width as isize - 1);
                if hi_c < lo_c as isize {
                    None
                } else {
                    Some((lo_c, hi_c as usize))
                }
            };

        for t in 0..n_tiles + n_layers {
            // §Watchdog: a zombified worker observes cancellation at
            // tile granularity and aborts the doomed band — the
            // partial result is discarded by the caller's generation
            // check, never delivered.
            if scratch.cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
                break;
            }
            // -- 1. load the input tile from DRAM into the ping buffer --
            let mut cur_buf = 0usize; // buffer holding map k-1's region
            let in_region = if t < n_tiles {
                region(t, 0)
            } else {
                None
            };
            if let Some((lo, hi)) = in_region {
                for c in lo..=hi {
                    band.column_into(c, &mut scratch.colbuf);
                    ping[0].write((c - lo) * col_stride, &scratch.colbuf);
                    // residual ring keeps the anchor columns
                    residual.write(
                        (c % res_cols) * ch0 * cfg.tile_rows,
                        &scratch.colbuf,
                    );
                }
                // push the sliding last-2 window of the input map
                push_two_cols(
                    band,
                    0,
                    hi.saturating_sub(1),
                    hi,
                    &mut scratch.payload,
                );
                queue.push_back(
                    EntryLabel { tile: t, map: 0 },
                    &scratch.payload,
                );
                cur_cols[0] = Some((hi.saturating_sub(1), hi));
                stats.tiles += 1;
            }

            // -- 2. run the L convs of this tile step, tilted ----------
            // prev-tile region of map k-1 while it was current
            for k in 1..=n_layers {
                let layer = &pm.layers[k - 1];
                // consume the overlap entry of map k-1 from tile t-1
                let overlap_cols: Option<(usize, usize)> = if t >= 1 {
                    prev_cols[k - 1].take().map(|cols| {
                        let label = EntryLabel {
                            tile: t - 1,
                            map: k - 1,
                        };
                        queue.read_front_into(label, &mut scratch.overlap);
                        queue.pop_front(label);
                        cols
                    })
                } else {
                    None
                };

                let Some((lo, hi)) = region(t, k) else {
                    continue;
                };
                let cur = region(t, k - 1); // map k-1 region this tile
                let cin = layer.cin;
                let pw = hi - lo + 3;
                let mut patch = scratch.take_u8(rows + 2, pw, cin);
                for (px, c_img) in
                    (lo as isize - 1..=hi as isize + 1).enumerate()
                {
                    if c_img < 0 || c_img >= width as isize {
                        continue; // image border: stays zero
                    }
                    let c_img = c_img as usize;
                    let from_cur = cur
                        .map(|(cl, chi)| c_img >= cl && c_img <= chi)
                        .unwrap_or(false);
                    let col: &[u8] = if from_cur {
                        // PANIC: `from_cur` is only true when `cur`
                        // is Some (checked by the map just above).
                        let (cl, _) = cur.unwrap();
                        ping[cur_buf]
                            .read((c_img - cl) * col_stride, rows * cin)
                    } else {
                        overlap_col(
                            overlap_cols,
                            &scratch.overlap,
                            c_img,
                            rows * cin,
                            t,
                            k,
                        )
                    };
                    // place into the patch (vertical zero halo = seam)
                    for y in 0..rows {
                        let dst = patch.idx(y + 1, px, 0);
                        patch.data[dst..dst + cin]
                            .copy_from_slice(&col[y * cin..(y + 1) * cin]);
                    }
                }

                let (out, cost) = engine.run_layer(&patch, layer, scratch);
                scratch.recycle_u8(patch);
                stats.compute_cycles +=
                    cost.cycles + cfg.buffer_swap_cycles;
                stats.mac_ops += cost.mac_ops;
                stats.mac_slots += cost.mac_slots
                    + cfg.buffer_swap_cycles * cfg.total_macs() as u64;

                match out {
                    LayerOut::U8(map_k) => {
                        // store region into the other ping buffer
                        let dst = 1 - cur_buf;
                        for c in lo..=hi {
                            map_k.column_into(c - lo, &mut scratch.colbuf);
                            ping[dst].write(
                                (c - lo) * col_stride,
                                &scratch.colbuf,
                            );
                        }
                        // push the sliding last-2 window of map k
                        if k < n_layers {
                            let (c1, c2) = if hi > lo {
                                (hi - 1, hi)
                            } else {
                                (hi, hi) // single col: duplicate; the
                                         // left one comes from prev win
                            };
                            push_two_cols(
                                &map_k,
                                lo,
                                c1,
                                c2,
                                &mut scratch.payload,
                            );
                            queue.push_back(
                                EntryLabel { tile: t, map: k },
                                &scratch.payload,
                            );
                            cur_cols[k] = Some((c1, c2));
                        }
                        scratch.recycle_u8(map_k);
                        cur_buf = dst;
                    }
                    LayerOut::I32(pre) => {
                        // final conv: residual add + shuffle, column-wise
                        debug_assert_eq!(k, n_layers);
                        let tile_w = hi - lo + 1;
                        let mut anchor = scratch.take_u8(rows, tile_w, ch0);
                        for c in lo..=hi {
                            let bytes = residual.read(
                                (c % res_cols) * ch0 * cfg.tile_rows,
                                rows * ch0,
                            );
                            anchor.set_column(c - lo, bytes);
                        }
                        let mut hr_tile = scratch.take_u8(
                            rows * scale,
                            tile_w * scale,
                            ch0,
                        );
                        add_anchor_and_shuffle_into(
                            &pre, &anchor, scale, &mut hr_tile,
                        );
                        // blit HR tile rows into the band (contiguous)
                        let row_bytes = hr_tile.w * ch0;
                        for y in 0..hr_tile.h {
                            let src = y * row_bytes;
                            let dst = hr_band.idx(y, lo * scale, 0);
                            hr_band.data[dst..dst + row_bytes]
                                .copy_from_slice(
                                    &hr_tile.data[src..src + row_bytes],
                                );
                        }
                        scratch.recycle_u8(anchor);
                        scratch.recycle_u8(hr_tile);
                        scratch.recycle_i32(pre);
                    }
                }
            }

            // entering the next tile step: this tile's windows become
            // the previous tile's
            std::mem::swap(&mut prev_cols, &mut cur_cols);
            cur_cols.fill(None);
        }

        stats.sram_reads = ping[0].reads()
            + ping[1].reads()
            + queue.sram().reads()
            + residual.reads();
        stats.sram_writes = ping[0].writes()
            + ping[1].writes()
            + queue.sram().writes()
            + residual.writes();
        stats.peak_pingpong_bytes =
            (ping[0].high_water() + ping[1].high_water()) as u64;
        stats.overlap_bytes = queue.capacity_bytes() as u64;
        stats.residual_bytes = residual.capacity() as u64;
        assert!(
            queue.max_count() <= n_layers + 2,
            "overlap occupancy {} exceeded L+2",
            queue.max_count()
        );
        (hr_band, stats)
    }

    /// Frame-level prepared path: bands share the packed weights and
    /// the scratch arena (the shared [`super::run_frame_bands`]
    /// driver, so the tilted and streaming frame paths cannot drift).
    pub fn run_frame_prepared(
        &self,
        frame: &Tensor<u8>,
        pm: &PreparedModel,
        cfg: &AcceleratorConfig,
        scratch: &mut Scratch,
    ) -> FrameResult {
        run_frame_bands(
            frame,
            pm,
            cfg.tile_rows,
            scratch,
            |band, scratch| self.run_band_prepared(band, pm, cfg, scratch),
        )
    }
}

/// Append the two columns `c1`, `c2` (image-space, offset by `offset`
/// into `t`) into the reusable payload buffer.
fn push_two_cols(
    t: &Tensor<u8>,
    offset: usize,
    c1: usize,
    c2: usize,
    buf: &mut Vec<u8>,
) {
    buf.clear();
    for &c in &[c1, c2] {
        let x = c - offset;
        for y in 0..t.h {
            let base = t.idx(y, x, 0);
            buf.extend_from_slice(&t.data[base..base + t.c]);
        }
    }
}

/// Borrow one overlap-sourced column out of the popped payload bytes.
fn overlap_col<'a>(
    cols: Option<(usize, usize)>,
    bytes: &'a [u8],
    c_img: usize,
    col_bytes: usize,
    t: usize,
    k: usize,
) -> &'a [u8] {
    let (c1, c2) = cols.unwrap_or_else(|| {
        // PANIC: reaching this arm means the tilt schedule itself is
        // wrong (a column was consumed that was never produced) —
        // a scheduler bug, not a data-dependent condition.
        panic!("tilt violated: tile {t} conv {k} needs col {c_img} with no overlap entry")
    });
    let half = bytes.len() / 2;
    if c_img == c1 {
        &bytes[..half][..col_bytes]
    } else if c_img == c2 {
        &bytes[half..][..col_bytes]
    } else {
        // PANIC: same invariant as above — the overlap entry exists
        // but holds different columns than the schedule demands,
        // which only a scheduler bug can produce.
        panic!(
            "tilt violated: tile {t} conv {k} needs col {c_img}, overlap has ({c1},{c2})"
        )
    }
}

impl FusionScheduler for TiltedScheduler {
    fn run_frame(
        &self,
        frame: &Tensor<u8>,
        qm: &QuantModel,
        cfg: &AcceleratorConfig,
    ) -> FrameResult {
        let pm = PreparedModel::new(qm);
        let mut scratch = Scratch::new();
        self.run_frame_prepared(frame, &pm, cfg, &mut scratch)
    }

    fn kind(&self) -> FusionKind {
        FusionKind::Tilted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::model::QuantModel;
    use crate::reference;
    use crate::util::Xoshiro256pp;

    fn rand_frame(h: usize, w: usize, c: usize, seed: u64) -> Tensor<u8> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut t = Tensor::new(h, w, c);
        rng.fill_u8(&mut t.data);
        t
    }

    fn small_cfg(rows: usize, cols: usize) -> AcceleratorConfig {
        AcceleratorConfig {
            tile_rows: rows,
            tile_cols: cols,
            ..AcceleratorConfig::paper()
        }
    }

    #[test]
    fn band_matches_reference_exactly() {
        let qm = QuantModel::test_model(3, 3, 5, 3, 21);
        let band = rand_frame(6, 24, 3, 1);
        let cfg = small_cfg(6, 4);
        let (hr, _) = TiltedScheduler::default().run_band(&band, &qm, &cfg);
        let want = reference::forward_int(&band, &qm);
        assert_eq!(hr.data, want.data, "tilted band differs from reference");
    }

    #[test]
    fn band_matches_reference_ragged_width() {
        // width not a multiple of the tile: drain logic + clamping
        let qm = QuantModel::test_model(4, 3, 6, 3, 5);
        let band = rand_frame(7, 19, 3, 9);
        let cfg = small_cfg(7, 4);
        let (hr, _) = TiltedScheduler::default().run_band(&band, &qm, &cfg);
        let want = reference::forward_int(&band, &qm);
        assert_eq!(hr.data, want.data);
    }

    #[test]
    fn prepared_band_reuses_scratch_across_bands() {
        // one PreparedModel + Scratch serving several bands must match
        // the one-shot wrapper bit for bit
        let qm = QuantModel::test_model(3, 3, 5, 3, 33);
        let cfg = small_cfg(6, 4);
        let pm = PreparedModel::new(&qm);
        let mut scratch = Scratch::new();
        let sched = TiltedScheduler::default();
        for seed in 0..3u64 {
            let band = rand_frame(6, 17, 3, 40 + seed);
            let (a, sa) =
                sched.run_band_prepared(&band, &pm, &cfg, &mut scratch);
            let (b, sb) = sched.run_band(&band, &qm, &cfg);
            assert_eq!(a.data, b.data, "band {seed}");
            assert_eq!(sa, sb, "band {seed} stats");
        }
    }

    #[test]
    fn frame_splits_into_bands_with_seams() {
        let qm = QuantModel::test_model(2, 3, 4, 3, 13);
        let frame = rand_frame(12, 16, 3, 3);
        let cfg = small_cfg(6, 4);
        let res = TiltedScheduler::default().run_frame(&frame, &qm, &cfg);
        // band-by-band reference (zero-padded seams)
        for (i, (y0, y1)) in band_ranges(12, 6).into_iter().enumerate() {
            let band = band_of(&frame, y0, y1);
            let want = reference::forward_int(&band, &qm);
            let got = &res.hr.data[y0 * 3 * res.hr.w * 3
                ..y1 * 3 * res.hr.w * 3];
            assert_eq!(got, &want.data[..], "band {i}");
        }
    }

    #[test]
    fn overlap_occupancy_is_l_plus_1() {
        // the queue never exceeds L+1 entries; capacity is L+2 (eq. 2)
        let qm = QuantModel::test_model(3, 3, 5, 3, 2);
        let band = rand_frame(6, 20, 3, 4);
        let cfg = small_cfg(6, 4);
        // run_band asserts max_count <= L+2 internally
        let (_, stats) = TiltedScheduler::default().run_band(&band, &qm, &cfg);
        assert_eq!(
            stats.overlap_bytes,
            ((qm.n_layers() + 2) * 6 * 2 * qm.max_channels()) as u64
        );
    }

    #[test]
    fn paper_buffer_budget_table2() {
        // APBN-shaped model, paper geometry: the Table II numbers
        let qm = QuantModel::test_model(7, 3, 28, 3, 0);
        let band = rand_frame(60, 64, 3, 8);
        let cfg = AcceleratorConfig::paper();
        let (_, stats) =
            TiltedScheduler::default().run_band(&band, &qm, &cfg);
        assert_eq!(stats.overlap_bytes, 9 * 60 * 2 * 28); // 30240 = 30.24 KB
        assert_eq!(stats.residual_bytes, 3 * 60 * (8 + 7)); // 2700 = 2.7 KB
        assert!(stats.peak_pingpong_bytes <= 2 * 60 * 8 * 28);
    }

    #[test]
    fn cancelled_scratch_aborts_the_band_early() {
        let qm = QuantModel::test_model(3, 3, 5, 3, 21);
        let band = rand_frame(6, 24, 3, 1);
        let cfg = small_cfg(6, 4);
        let pm = PreparedModel::new(&qm);
        let mut scratch = Scratch::new();
        let sched = TiltedScheduler::default();
        // an uncancelled token changes nothing: bit-identical output
        let tok = crate::util::cancel::CancelToken::new();
        scratch.cancel = Some(tok.clone());
        let (hr, _) =
            sched.run_band_prepared(&band, &pm, &cfg, &mut scratch);
        let want = reference::forward_int(&band, &qm);
        assert_eq!(hr.data, want.data);
        // a pre-cancelled token aborts before any tile runs
        tok.cancel();
        let (hr, stats) =
            sched.run_band_prepared(&band, &pm, &cfg, &mut scratch);
        assert!(hr.data.iter().all(|&b| b == 0), "aborted band is blank");
        assert_eq!(stats.tiles, 0, "no tile ran after cancellation");
    }

    #[test]
    fn cycle_exact_fidelity_agrees() {
        let qm = QuantModel::test_model(2, 3, 4, 3, 17);
        let band = rand_frame(5, 12, 3, 6);
        let cfg = small_cfg(5, 4);
        let (a, sa) = TiltedScheduler::default().run_band(&band, &qm, &cfg);
        let (c, sc) =
            TiltedScheduler::cycle_exact().run_band(&band, &qm, &cfg);
        assert_eq!(a.data, c.data);
        assert_eq!(sa.compute_cycles, sc.compute_cycles);
        assert_eq!(sa.mac_ops, sc.mac_ops);
    }
}
