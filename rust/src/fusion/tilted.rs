//! Tilted layer fusion (Section II) against real memory models.
//!
//! A band of `R` rows is processed in parallelepiped tiles of `C`
//! columns: the region of feature map *k* (output of conv *k-1*, map 0 =
//! the LR input) for tile *t* is columns `[tC - k, (t+1)C - 1 - k]` —
//! each deeper layer shifts one pixel left (Fig. 2).  Consequences,
//! all modelled here explicitly:
//!
//! * the right boundary of conv *k*'s input (column `hi+1`) is exactly
//!   the last column conv *k-1* just produced in this tile — "ready
//!   without waiting" (the red pixels of Fig. 2);
//! * the left boundary (columns `lo-1`, `lo`) is the previous tile's two
//!   rightmost columns of map *k-1*, read from the queue-addressed
//!   [`OverlapQueue`] (the blue pixels);
//! * the residual anchor of the final layer lags `L` columns behind the
//!   input stream, so the residual ring holds `C + L` input columns —
//!   the paper's eq. (3);
//! * vertical band seams are zero-padded: the only information loss.
//!
//! The band output is bit-identical to monolithic band inference
//! (`reference::forward_int` on the band) — asserted by
//! `rust/tests/fusion_exactness.rs`.

use crate::config::{AcceleratorConfig, FidelityKind, FusionKind};
use crate::model::{QuantModel, Tensor};
use crate::reference::add_anchor_and_shuffle;
use crate::sim::engine::{
    AnalyticEngine, CycleExactEngine, LayerOut, TileEngine,
};
use crate::sim::{RunStats, Sram};

use super::overlap::{EntryLabel, OverlapQueue};
use super::{band_of, band_ranges, base_frame_traffic, FrameResult, FusionScheduler};

/// The paper's scheduler.
#[derive(Clone, Copy, Debug)]
pub struct TiltedScheduler {
    pub fidelity: FidelityKind,
}

impl Default for TiltedScheduler {
    fn default() -> Self {
        Self {
            fidelity: FidelityKind::Analytic,
        }
    }
}

impl TiltedScheduler {
    pub fn cycle_exact() -> Self {
        Self {
            fidelity: FidelityKind::CycleExact,
        }
    }

    fn engine(&self) -> Box<dyn TileEngine> {
        match self.fidelity {
            FidelityKind::Analytic => Box::new(AnalyticEngine::paper()),
            FidelityKind::CycleExact => Box::new(CycleExactEngine::paper()),
        }
    }

    /// Run one band; returns the HR band and its stats.
    pub fn run_band(
        &self,
        band: &Tensor<u8>,
        qm: &QuantModel,
        cfg: &AcceleratorConfig,
    ) -> (Tensor<u8>, RunStats) {
        let engine = self.engine();
        let rows = band.h;
        let width = band.w;
        let c_tile = cfg.tile_cols.max(2); // sliding-2 window needs C >= 2
        let n_layers = qm.n_layers();
        let max_ch = qm.max_channels();
        let ch0 = qm.layers[0].cin;
        let scale = qm.scale;

        // --- on-chip memories, provisioned per eqs. (1)-(3) -----------
        let col_stride = cfg.tile_rows * max_ch; // bytes per buffered column
        let mut ping = [
            Sram::new("ping_a", cfg.tile_rows * c_tile * max_ch),
            Sram::new("ping_b", cfg.tile_rows * c_tile * max_ch),
        ];
        let mut queue = OverlapQueue::new(
            n_layers + 2,
            cfg.tile_rows * 2 * max_ch,
        );
        let res_cols = c_tile + n_layers;
        let mut residual =
            Sram::new("residual", ch0 * cfg.tile_rows * res_cols);

        // functional bookkeeping of what each queue entry contains
        // (payload bytes + image-space column indices), keyed by
        // (tile, map); the authoritative bytes live in the queue SRAM
        // and are read back through it
        let mut pending: std::collections::HashMap<
            (usize, usize),
            (usize, usize),
        > = std::collections::HashMap::new();

        // region of map k-1 currently resident in the ping buffer
        // (cur_lo, width) per tile step; index of buffer holding it
        let mut stats = RunStats::default();
        let mut hr_band: Tensor<u8> =
            Tensor::new(rows * scale, width * scale, ch0);

        let n_tiles = width.div_ceil(c_tile);
        let region =
            |t: usize, k: usize| -> Option<(usize, usize)> {
                let lo = (t * c_tile) as isize - k as isize;
                let hi = ((t + 1) * c_tile) as isize - 1 - k as isize;
                let lo_c = lo.max(0) as usize;
                let hi_c = hi.min(width as isize - 1);
                if hi_c < lo_c as isize {
                    None
                } else {
                    Some((lo_c, hi_c as usize))
                }
            };

        for t in 0..n_tiles + n_layers {
            // -- 1. load the input tile from DRAM into the ping buffer --
            let mut cur_buf = 0usize; // buffer holding map k-1's region
            let in_region = if t < n_tiles {
                region(t, 0)
            } else {
                None
            };
            if let Some((lo, hi)) = in_region {
                for c in lo..=hi {
                    let col = band.column(c);
                    ping[0].write((c - lo) * col_stride, &col);
                    // residual ring keeps the anchor columns
                    residual
                        .write((c % res_cols) * ch0 * cfg.tile_rows, &col);
                }
                // push the sliding last-2 window of the input map
                let payload = two_col_payload(
                    &shift_map(band, 0),
                    hi.saturating_sub(1),
                    hi,
                );
                queue.push_back(EntryLabel { tile: t, map: 0 }, &payload);
                pending.insert((t, 0), (hi.saturating_sub(1), hi));
                stats.tiles += 1;
            }

            // -- 2. run the L convs of this tile step, tilted ----------
            // prev-tile region of map k-1 while it was current
            for k in 1..=n_layers {
                let layer = &qm.layers[k - 1];
                // consume the overlap entry of map k-1 from tile t-1
                let prev_payload: Option<(Vec<u8>, (usize, usize))> = if t
                    >= 1
                {
                    pending.remove(&(t - 1, k - 1)).map(|cols| {
                        let label = EntryLabel {
                            tile: t - 1,
                            map: k - 1,
                        };
                        let bytes = queue.read_front(label);
                        queue.pop_front(label);
                        (bytes, cols)
                    })
                } else {
                    None
                };

                let Some((lo, hi)) = region(t, k) else {
                    continue;
                };
                let cur = region(t, k - 1); // map k-1 region this tile
                let cin = layer.cin;
                let pw = hi - lo + 3;
                let mut patch: Tensor<u8> =
                    Tensor::new(rows + 2, pw, cin);
                for (px, c_img) in
                    (lo as isize - 1..=hi as isize + 1).enumerate()
                {
                    if c_img < 0 || c_img >= width as isize {
                        continue; // image border: stays zero
                    }
                    let c_img = c_img as usize;
                    let col: Vec<u8> = if let Some((cl, chi)) = cur {
                        if c_img >= cl && c_img <= chi {
                            ping[cur_buf]
                                .read(
                                    (c_img - cl) * col_stride,
                                    rows * cin,
                                )
                                .to_vec()
                        } else {
                            read_overlap_col(
                                &prev_payload,
                                c_img,
                                rows * cin,
                                t,
                                k,
                            )
                        }
                    } else {
                        read_overlap_col(
                            &prev_payload,
                            c_img,
                            rows * cin,
                            t,
                            k,
                        )
                    };
                    // place into the patch (vertical zero halo = seam)
                    for y in 0..rows {
                        for ch in 0..cin {
                            patch.set(
                                y + 1,
                                px,
                                ch,
                                col[y * cin + ch],
                            );
                        }
                    }
                }

                let (out, cost) = engine.run_layer(&patch, layer);
                stats.compute_cycles +=
                    cost.cycles + cfg.buffer_swap_cycles;
                stats.mac_ops += cost.mac_ops;
                stats.mac_slots += cost.mac_slots
                    + cfg.buffer_swap_cycles * cfg.total_macs() as u64;

                match out {
                    LayerOut::U8(map_k) => {
                        // store region into the other ping buffer
                        let dst = 1 - cur_buf;
                        for c in lo..=hi {
                            let col = map_k.column(c - lo);
                            ping[dst]
                                .write((c - lo) * col_stride, &col);
                        }
                        // push the sliding last-2 window of map k
                        if k < n_layers {
                            let (c1, c2) = if hi > lo {
                                (hi - 1, hi)
                            } else {
                                (hi, hi) // single col: duplicate; the
                                         // left one comes from prev win
                            };
                            let payload =
                                two_col_payload(&shift_map(&map_k, lo), c1, c2);
                            queue.push_back(
                                EntryLabel { tile: t, map: k },
                                &payload,
                            );
                            pending.insert((t, k), (c1, c2));
                        }
                        cur_buf = dst;
                    }
                    LayerOut::I32(pre) => {
                        // final conv: residual add + shuffle, column-wise
                        debug_assert_eq!(k, n_layers);
                        let mut anchor: Tensor<u8> =
                            Tensor::new(rows, hi - lo + 1, ch0);
                        for c in lo..=hi {
                            let bytes = residual.read(
                                (c % res_cols) * ch0 * cfg.tile_rows,
                                rows * ch0,
                            );
                            anchor.set_column(c - lo, bytes);
                        }
                        let hr_tile =
                            add_anchor_and_shuffle(&pre, &anchor, scale);
                        for y in 0..hr_tile.h {
                            for x in 0..hr_tile.w {
                                for ch in 0..ch0 {
                                    hr_band.set(
                                        y,
                                        lo * scale + x,
                                        ch,
                                        hr_tile.get(y, x, ch),
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }

        stats.sram_reads = ping[0].reads()
            + ping[1].reads()
            + queue.sram().reads()
            + residual.reads();
        stats.sram_writes = ping[0].writes()
            + ping[1].writes()
            + queue.sram().writes()
            + residual.writes();
        stats.peak_pingpong_bytes =
            (ping[0].high_water() + ping[1].high_water()) as u64;
        stats.overlap_bytes = queue.capacity_bytes() as u64;
        stats.residual_bytes = residual.capacity() as u64;
        assert!(
            queue.max_count() <= n_layers + 2,
            "overlap occupancy {} exceeded L+2",
            queue.max_count()
        );
        (hr_band, stats)
    }
}

/// Payload = the two columns `c1`, `c2` of a map tensor indexed from 0.
fn two_col_payload(map: &MapView, c1: usize, c2: usize) -> Vec<u8> {
    let mut p = map.column(c1);
    p.extend(map.column(c2));
    p
}

/// Minimal column view abstraction so both band input (full width) and
/// freshly computed region maps (offset by `lo`) can feed the payload
/// builder with *image-space* column indices.
struct MapViewOwned {
    t: Tensor<u8>,
    offset: usize,
}

type MapView = MapViewOwned;

impl MapViewOwned {
    fn column(&self, c_img: usize) -> Vec<u8> {
        self.t.column(c_img - self.offset)
    }
}

fn shift_map(t: &Tensor<u8>, offset: usize) -> MapViewOwned {
    MapViewOwned {
        t: t.clone(),
        offset,
    }
}

/// Read one overlap-sourced column out of the popped payload.
fn read_overlap_col(
    payload: &Option<(Vec<u8>, (usize, usize))>,
    c_img: usize,
    col_bytes: usize,
    t: usize,
    k: usize,
) -> Vec<u8> {
    let (bytes, (c1, c2)) = payload.as_ref().unwrap_or_else(|| {
        panic!("tilt violated: tile {t} conv {k} needs col {c_img} with no overlap entry")
    });
    let half = bytes.len() / 2;
    if c_img == *c1 {
        bytes[..half][..col_bytes].to_vec()
    } else if c_img == *c2 {
        bytes[half..][..col_bytes].to_vec()
    } else {
        panic!(
            "tilt violated: tile {t} conv {k} needs col {c_img}, overlap has ({c1},{c2})"
        )
    }
}

impl FusionScheduler for TiltedScheduler {
    fn run_frame(
        &self,
        frame: &Tensor<u8>,
        qm: &QuantModel,
        cfg: &AcceleratorConfig,
    ) -> FrameResult {
        let mut stats = RunStats::default();
        base_frame_traffic(frame, qm, &mut stats);
        let scale = qm.scale;
        let mut hr: Tensor<u8> =
            Tensor::new(frame.h * scale, frame.w * scale, frame.c);
        for (y0, y1) in band_ranges(frame.h, cfg.tile_rows) {
            let band = band_of(frame, y0, y1);
            let (hr_band, band_stats) = self.run_band(&band, qm, cfg);
            stats.merge(&band_stats);
            let dst0 = y0 * scale * hr.w * hr.c;
            hr.data[dst0..dst0 + hr_band.data.len()]
                .copy_from_slice(&hr_band.data);
        }
        FrameResult { hr, stats }
    }

    fn kind(&self) -> FusionKind {
        FusionKind::Tilted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::model::QuantModel;
    use crate::reference;
    use crate::util::Xoshiro256pp;

    fn rand_frame(h: usize, w: usize, c: usize, seed: u64) -> Tensor<u8> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut t = Tensor::new(h, w, c);
        rng.fill_u8(&mut t.data);
        t
    }

    fn small_cfg(rows: usize, cols: usize) -> AcceleratorConfig {
        AcceleratorConfig {
            tile_rows: rows,
            tile_cols: cols,
            ..AcceleratorConfig::paper()
        }
    }

    #[test]
    fn band_matches_reference_exactly() {
        let qm = QuantModel::test_model(3, 3, 5, 3, 21);
        let band = rand_frame(6, 24, 3, 1);
        let cfg = small_cfg(6, 4);
        let (hr, _) = TiltedScheduler::default().run_band(&band, &qm, &cfg);
        let want = reference::forward_int(&band, &qm);
        assert_eq!(hr.data, want.data, "tilted band differs from reference");
    }

    #[test]
    fn band_matches_reference_ragged_width() {
        // width not a multiple of the tile: drain logic + clamping
        let qm = QuantModel::test_model(4, 3, 6, 3, 5);
        let band = rand_frame(7, 19, 3, 9);
        let cfg = small_cfg(7, 4);
        let (hr, _) = TiltedScheduler::default().run_band(&band, &qm, &cfg);
        let want = reference::forward_int(&band, &qm);
        assert_eq!(hr.data, want.data);
    }

    #[test]
    fn frame_splits_into_bands_with_seams() {
        let qm = QuantModel::test_model(2, 3, 4, 3, 13);
        let frame = rand_frame(12, 16, 3, 3);
        let cfg = small_cfg(6, 4);
        let res = TiltedScheduler::default().run_frame(&frame, &qm, &cfg);
        // band-by-band reference (zero-padded seams)
        for (i, (y0, y1)) in band_ranges(12, 6).into_iter().enumerate() {
            let band = band_of(&frame, y0, y1);
            let want = reference::forward_int(&band, &qm);
            let got = &res.hr.data[y0 * 3 * res.hr.w * 3
                ..y1 * 3 * res.hr.w * 3];
            assert_eq!(got, &want.data[..], "band {i}");
        }
    }

    #[test]
    fn overlap_occupancy_is_l_plus_1() {
        // the queue never exceeds L+1 entries; capacity is L+2 (eq. 2)
        let qm = QuantModel::test_model(3, 3, 5, 3, 2);
        let band = rand_frame(6, 20, 3, 4);
        let cfg = small_cfg(6, 4);
        // run_band asserts max_count <= L+2 internally
        let (_, stats) = TiltedScheduler::default().run_band(&band, &qm, &cfg);
        assert_eq!(
            stats.overlap_bytes,
            ((qm.n_layers() + 2) * 6 * 2 * qm.max_channels()) as u64
        );
    }

    #[test]
    fn paper_buffer_budget_table2() {
        // APBN-shaped model, paper geometry: the Table II numbers
        let qm = QuantModel::test_model(7, 3, 28, 3, 0);
        let band = rand_frame(60, 64, 3, 8);
        let cfg = AcceleratorConfig::paper();
        let (_, stats) =
            TiltedScheduler::default().run_band(&band, &qm, &cfg);
        assert_eq!(stats.overlap_bytes, 9 * 60 * 2 * 28); // 30240 = 30.24 KB
        assert_eq!(stats.residual_bytes, 3 * 60 * (8 + 7)); // 2700 = 2.7 KB
        assert!(stats.peak_pingpong_bytes <= 2 * 60 * 8 * 28);
    }

    #[test]
    fn cycle_exact_fidelity_agrees() {
        let qm = QuantModel::test_model(2, 3, 4, 3, 17);
        let band = rand_frame(5, 12, 3, 6);
        let cfg = small_cfg(5, 4);
        let (a, sa) = TiltedScheduler::default().run_band(&band, &qm, &cfg);
        let (c, sc) =
            TiltedScheduler::cycle_exact().run_band(&band, &qm, &cfg);
        assert_eq!(a.data, c.data);
        assert_eq!(sa.compute_cycles, sc.compute_cycles);
        assert_eq!(sa.mac_ops, sc.mac_ops);
    }
}
