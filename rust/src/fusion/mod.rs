//! Fusion schedulers (DESIGN.md S12): the paper's tilted layer fusion
//! and the three baselines it is evaluated against.
//!
//! | scheduler        | paper ref | output                        |
//! |------------------|-----------|-------------------------------|
//! | [`TiltedScheduler`]       | Section II (this paper) | exact within bands |
//! | [`ClassicalScheduler`]    | Alwani fused-layer [14] | exact (recompute halos) |
//! | [`BlockConvScheduler`]    | block convolution [15]  | lossy at every tile edge |
//! | [`LayerByLayerScheduler`] | [11]/[12] style         | exact, DRAM-heavy |
//!
//! Every scheduler consumes a uint8 LR frame and produces the uint8 HR
//! frame plus [`RunStats`] (cycles, MAC utilization, DRAM/SRAM traffic,
//! buffer footprints) — the raw material for Tables I/II, Fig. 1 and the
//! DRAM-bandwidth experiment.

//! On top of the simulated schedules sits the serving-side
//! [`StreamingScheduler`] (§Streaming, `streaming.rs`): a row-ring
//! fused executor that is bit-identical to [`TiltedScheduler`] per
//! band but keeps only 3-row line buffers per layer — no SRAM model,
//! no per-tile staging — and is the coordinator's default executor.

pub mod block_conv;
pub mod classical;
pub mod layer_by_layer;
pub mod overlap;
pub mod streaming;
pub mod tilted;

pub use block_conv::BlockConvScheduler;
pub use classical::ClassicalScheduler;
pub use layer_by_layer::LayerByLayerScheduler;
pub use overlap::OverlapQueue;
pub use streaming::StreamingScheduler;
pub use tilted::TiltedScheduler;

use crate::config::{AcceleratorConfig, FusionKind};
use crate::model::{PreparedModel, QuantModel, Scratch, Tensor};
use crate::sim::RunStats;

/// Result of running one LR frame through a scheduler.
#[derive(Clone, Debug)]
pub struct FrameResult {
    pub hr: Tensor<u8>,
    pub stats: RunStats,
}

/// A frame-level execution schedule on the simulated accelerator.
pub trait FusionScheduler {
    fn run_frame(
        &self,
        frame: &Tensor<u8>,
        qm: &QuantModel,
        cfg: &AcceleratorConfig,
    ) -> FrameResult;

    fn kind(&self) -> FusionKind;
}

/// Statically-dispatched scheduler covering every [`FusionKind`].
///
/// An enum instead of a boxed trait object keeps the simulator's
/// dispatch static end to end (sr-lint rule L5 bans trait objects in
/// `fusion/` and `reference/`, matching the PR-5 serving-path
/// invariant): callers pay one `match` per frame instead of a heap
/// allocation plus vtable indirection.
#[derive(Clone, Debug)]
pub enum AnyScheduler {
    Tilted(TiltedScheduler),
    Classical(ClassicalScheduler),
    BlockConv(BlockConvScheduler),
    LayerByLayer(LayerByLayerScheduler),
}

impl FusionScheduler for AnyScheduler {
    fn run_frame(
        &self,
        frame: &Tensor<u8>,
        qm: &QuantModel,
        cfg: &AcceleratorConfig,
    ) -> FrameResult {
        match self {
            AnyScheduler::Tilted(s) => s.run_frame(frame, qm, cfg),
            AnyScheduler::Classical(s) => s.run_frame(frame, qm, cfg),
            AnyScheduler::BlockConv(s) => s.run_frame(frame, qm, cfg),
            AnyScheduler::LayerByLayer(s) => s.run_frame(frame, qm, cfg),
        }
    }

    fn kind(&self) -> FusionKind {
        match self {
            AnyScheduler::Tilted(s) => s.kind(),
            AnyScheduler::Classical(s) => s.kind(),
            AnyScheduler::BlockConv(s) => s.kind(),
            AnyScheduler::LayerByLayer(s) => s.kind(),
        }
    }
}

/// Construct the scheduler for a [`FusionKind`].
pub fn make_scheduler(kind: FusionKind) -> AnyScheduler {
    match kind {
        FusionKind::Tilted => {
            AnyScheduler::Tilted(TiltedScheduler::default())
        }
        FusionKind::Classical => {
            AnyScheduler::Classical(ClassicalScheduler::default())
        }
        FusionKind::BlockConv => {
            AnyScheduler::BlockConv(BlockConvScheduler::default())
        }
        FusionKind::LayerByLayer => {
            AnyScheduler::LayerByLayer(LayerByLayerScheduler::default())
        }
    }
}

/// Shared per-frame DRAM accounting: every schedule reads the LR frame
/// and the weights once and writes the HR frame once; schedulers add
/// their own intermediate traffic on top.
pub(crate) fn base_frame_traffic(
    frame: &Tensor<u8>,
    qm: &QuantModel,
    stats: &mut RunStats,
) {
    base_frame_traffic_parts(
        frame,
        qm.weight_bytes() + qm.bias_bytes(),
        qm.scale,
        stats,
    );
}

/// [`base_frame_traffic`] from pre-computed model byte counts — the
/// prepared execution paths carry these in
/// [`crate::model::PreparedModel`] instead of a `QuantModel`.
pub(crate) fn base_frame_traffic_parts(
    frame: &Tensor<u8>,
    model_bytes: usize,
    scale: usize,
    stats: &mut RunStats,
) {
    stats.dram_read_bytes += frame.byte_len() as u64;
    stats.dram_read_bytes += model_bytes as u64;
    stats.dram_write_bytes +=
        (frame.h * scale * frame.w * scale * frame.c) as u64;
}

/// The one frame→bands driver shared by the fused band executors
/// (tilted and streaming): base DRAM accounting, `band_rows` split,
/// per-band execution via `run_band`, HR blit, stats merge, HR-band
/// recycling.  Keeping it in one place means the two executors'
/// frame paths cannot drift (band split or accounting changes apply
/// to both by construction).
pub(crate) fn run_frame_bands(
    frame: &Tensor<u8>,
    pm: &PreparedModel,
    band_rows: usize,
    scratch: &mut Scratch,
    mut run_band: impl FnMut(
        &Tensor<u8>,
        &mut Scratch,
    ) -> (Tensor<u8>, RunStats),
) -> FrameResult {
    let mut stats = RunStats::default();
    base_frame_traffic_parts(
        frame,
        pm.weight_bytes + pm.bias_bytes,
        pm.scale,
        &mut stats,
    );
    let scale = pm.scale;
    let mut hr: Tensor<u8> =
        Tensor::new(frame.h * scale, frame.w * scale, frame.c);
    for (y0, y1) in band_ranges(frame.h, band_rows) {
        let band = band_of(frame, y0, y1);
        let (hr_band, band_stats) = run_band(&band, scratch);
        stats.merge(&band_stats);
        let dst0 = y0 * scale * hr.w * hr.c;
        hr.data[dst0..dst0 + hr_band.data.len()]
            .copy_from_slice(&hr_band.data);
        scratch.recycle_u8(hr_band);
    }
    FrameResult { hr, stats }
}

/// Split a frame height into bands of `rows` (last band may be short).
///
/// Shared with the serving layer: `coordinator::shard` reuses the same
/// split so pipeline-level band sharding aligns with the fusion
/// scheduler's bands.
pub fn band_ranges(h: usize, rows: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut y = 0;
    while y < h {
        out.push((y, (y + rows).min(h)));
        y += rows;
    }
    out
}

/// Extract rows `[y0, y1)` of a tensor.
pub fn band_of(frame: &Tensor<u8>, y0: usize, y1: usize) -> Tensor<u8> {
    Tensor::from_vec(
        y1 - y0,
        frame.w,
        frame.c,
        frame.data[y0 * frame.w * frame.c..y1 * frame.w * frame.c].to_vec(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn band_ranges_cover_exactly() {
        assert_eq!(band_ranges(360, 60), {
            let mut v = Vec::new();
            for i in 0..6 {
                v.push((i * 60, (i + 1) * 60));
            }
            v
        });
        assert_eq!(band_ranges(70, 60), vec![(0, 60), (60, 70)]);
        assert_eq!(band_ranges(5, 60), vec![(0, 5)]);
    }

    #[test]
    fn make_scheduler_kinds() {
        for k in FusionKind::ALL {
            assert_eq!(make_scheduler(k).kind(), k);
        }
    }
}
