//! Block convolution baseline (Li et al. [15]): fused rectangular tiles
//! with **no** halo — every tile is zero-padded as if it were a whole
//! image, so information is lost on all four tile edges (Fig. 1a).
//!
//! Cheap (no overlap storage, no recompute) but lossy: the HR output
//! differs from the reference, increasingly so as tiles shrink — the
//! effect `benches/fig1_boundary.rs` quantifies.
//!
//! §Microkernel: each tile's SAME conv chain runs the prepared row
//! kernels, which drive the register-blocked strip microkernel.

use crate::config::{AcceleratorConfig, FusionKind};
use crate::model::{PreparedModel, QuantModel, Scratch, Tensor};
use crate::reference::{
    add_anchor_and_shuffle_into, conv3x3_final_prepared,
    conv3x3_relu_prepared,
};
use crate::sim::engine::{layer_cycles, EngineGeometry};
use crate::sim::RunStats;

use super::{base_frame_traffic, FrameResult, FusionScheduler};

/// Fused tiles with discarded boundaries.
#[derive(Clone, Copy, Debug)]
pub struct BlockConvScheduler {
    pub tile_rows: usize,
    pub tile_cols: usize,
}

impl Default for BlockConvScheduler {
    fn default() -> Self {
        Self {
            tile_rows: 60,
            tile_cols: 60,
        }
    }
}

impl BlockConvScheduler {
    /// Fraction of LR pixels whose receptive field is truncated by tile
    /// boundaries — the "area affected by information loss" of Fig. 1.
    /// `halo` = network receptive-field radius (= n_layers for 3x3s).
    pub fn affected_fraction(
        frame_h: usize,
        frame_w: usize,
        tile_rows: usize,
        tile_cols: usize,
        halo: usize,
    ) -> f64 {
        let mut affected = 0usize;
        let mut total = 0usize;
        let mut ty = 0;
        while ty < frame_h {
            let th = tile_rows.min(frame_h - ty);
            let mut tx = 0;
            while tx < frame_w {
                let tw = tile_cols.min(frame_w - tx);
                for y in 0..th {
                    for x in 0..tw {
                        total += 1;
                        // distance to the nearest *interior* tile edge
                        // (frame borders are real borders, not loss)
                        let d_top =
                            if ty == 0 { usize::MAX } else { y };
                        let d_bot = if ty + th == frame_h {
                            usize::MAX
                        } else {
                            th - 1 - y
                        };
                        let d_left =
                            if tx == 0 { usize::MAX } else { x };
                        let d_right = if tx + tw == frame_w {
                            usize::MAX
                        } else {
                            tw - 1 - x
                        };
                        let d =
                            d_top.min(d_bot).min(d_left).min(d_right);
                        if d < halo {
                            affected += 1;
                        }
                    }
                }
                tx += tile_cols;
            }
            ty += tile_rows;
        }
        affected as f64 / total as f64
    }
}

impl FusionScheduler for BlockConvScheduler {
    fn run_frame(
        &self,
        frame: &Tensor<u8>,
        qm: &QuantModel,
        cfg: &AcceleratorConfig,
    ) -> FrameResult {
        // prepared once per frame call; every tile shares it
        let pm = PreparedModel::new(qm);
        let mut scratch = Scratch::new();
        let mut stats = RunStats::default();
        base_frame_traffic(frame, qm, &mut stats);
        let geo = EngineGeometry {
            pe_blocks: cfg.pe_blocks,
            macs_per_cycle: cfg.total_macs(),
        };
        let scale = pm.scale;
        let mut hr: Tensor<u8> =
            Tensor::new(frame.h * scale, frame.w * scale, frame.c);
        let mut peak_ping = 0u64;

        let mut ty = 0;
        while ty < frame.h {
            let th = self.tile_rows.min(frame.h - ty);
            let mut tx = 0;
            while tx < frame.w {
                let tw = self.tile_cols.min(frame.w - tx);
                stats.tiles += 1;
                // the tile *is* the image: zero-padded SAME convs
                let mut tile = scratch.take_u8(th, tw, frame.c);
                for y in 0..th {
                    for x in 0..tw {
                        for c in 0..frame.c {
                            tile.set(y, x, c, frame.get(ty + y, tx + x, c));
                        }
                    }
                }
                for layer in &pm.layers {
                    let cost =
                        layer_cycles(th, tw, layer.cin, layer.cout, &geo);
                    stats.compute_cycles +=
                        cost.cycles + cfg.buffer_swap_cycles;
                    stats.mac_ops += cost.mac_ops;
                    stats.mac_slots += cost.mac_slots
                        + cfg.buffer_swap_cycles * cfg.total_macs() as u64;
                    peak_ping = peak_ping.max(
                        (th * tw * (layer.cin + layer.cout)) as u64,
                    );
                }
                let mut h: Option<Tensor<u8>> = None;
                for layer in &pm.layers[..pm.n_layers() - 1] {
                    let next = {
                        let input = h.as_ref().unwrap_or(&tile);
                        conv3x3_relu_prepared(input, layer, &mut scratch)
                    };
                    if let Some(old) = h.replace(next) {
                        scratch.recycle_u8(old);
                    }
                }
                let pre = {
                    let input = h.as_ref().unwrap_or(&tile);
                    conv3x3_final_prepared(
                        input,
                        // PANIC: PreparedModel::new rejects empty
                        // models; a last layer always exists.
                        pm.layers.last().unwrap(),
                        &mut scratch,
                    )
                };
                if let Some(old) = h.take() {
                    scratch.recycle_u8(old);
                }
                let mut hr_tile =
                    scratch.take_u8(th * scale, tw * scale, frame.c);
                add_anchor_and_shuffle_into(&pre, &tile, scale, &mut hr_tile);
                scratch.recycle_i32(pre);
                // blit HR tile rows into the frame (contiguous runs)
                let row_bytes = hr_tile.w * frame.c;
                for y in 0..hr_tile.h {
                    let src = y * row_bytes;
                    let dst = hr.idx(ty * scale + y, tx * scale, 0);
                    hr.data[dst..dst + row_bytes].copy_from_slice(
                        &hr_tile.data[src..src + row_bytes],
                    );
                }
                scratch.recycle_u8(hr_tile);
                scratch.recycle_u8(tile);
                tx += self.tile_cols;
            }
            ty += self.tile_rows;
        }
        stats.peak_pingpong_bytes = peak_ping;
        FrameResult { hr, stats }
    }

    fn kind(&self) -> FusionKind {
        FusionKind::BlockConv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::image::psnr_u8;
    use crate::image::ImageU8;
    use crate::model::QuantModel;
    use crate::reference;
    use crate::util::Xoshiro256pp;

    fn rand_frame(h: usize, w: usize, seed: u64) -> Tensor<u8> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut t = Tensor::new(h, w, 3);
        rng.fill_u8(&mut t.data);
        t
    }

    fn to_img(t: &Tensor<u8>) -> ImageU8 {
        ImageU8::from_vec(t.h, t.w, t.c, t.data.clone())
    }

    #[test]
    fn single_tile_is_exact() {
        let qm = QuantModel::test_model(2, 3, 4, 3, 3);
        let frame = rand_frame(8, 9, 1);
        let sched = BlockConvScheduler {
            tile_rows: 8,
            tile_cols: 9,
        };
        let res =
            sched.run_frame(&frame, &qm, &AcceleratorConfig::paper());
        assert_eq!(
            res.hr.data,
            reference::forward_int(&frame, &qm).data
        );
    }

    #[test]
    fn small_tiles_lose_information() {
        let qm = QuantModel::test_model(3, 3, 6, 3, 9);
        let frame = rand_frame(16, 16, 2);
        let res = BlockConvScheduler {
            tile_rows: 4,
            tile_cols: 4,
        }
        .run_frame(&frame, &qm, &AcceleratorConfig::paper());
        let want = reference::forward_int(&frame, &qm);
        assert_ne!(
            res.hr.data, want.data,
            "4x4 block conv should be lossy"
        );
        // but not garbage: still correlated with the exact output
        // (random-noise input is the worst case for boundary loss)
        let p = psnr_u8(&to_img(&res.hr), &to_img(&want));
        assert!(p > 8.0, "block conv PSNR collapsed: {p}");
    }

    #[test]
    fn affected_fraction_monotone_in_tile_size() {
        let f8 = BlockConvScheduler::affected_fraction(360, 640, 8, 8, 7);
        let f60 =
            BlockConvScheduler::affected_fraction(360, 640, 60, 60, 7);
        assert!(f8 > f60, "{f8} vs {f60}");
        assert!(f8 > 0.9, "8x8 tiles with halo 7 nearly all affected");
        assert!((0.0..=1.0).contains(&f60));
    }

    #[test]
    fn affected_fraction_zero_for_whole_frame_tile() {
        let f =
            BlockConvScheduler::affected_fraction(60, 80, 60, 80, 7);
        assert_eq!(f, 0.0);
    }
}
