//! Image substrate: u8/f32 HWC tensors, PPM I/O, resampling, quality
//! metrics, and the synthetic workload generator (DESIGN.md S6).

pub mod io;
pub mod metrics;
pub mod resize;
pub mod synth;

pub use io::{read_ppm, write_ppm};
pub use metrics::{mse, psnr, psnr_u8};
pub use resize::{bilinear_upsample, box_downsample_x3, nearest_upsample};
pub use synth::SceneGenerator;

/// An 8-bit HWC image (the accelerator's native pixel format).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ImageU8 {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<u8>,
}

impl ImageU8 {
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        Self {
            h,
            w,
            c,
            data: vec![0; h * w * c],
        }
    }

    pub fn from_vec(h: usize, w: usize, c: usize, data: Vec<u8>) -> Self {
        assert_eq!(data.len(), h * w * c, "image buffer size mismatch");
        Self { h, w, c, data }
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> u8 {
        self.data[(y * self.w + x) * self.c + ch]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: u8) {
        self.data[(y * self.w + x) * self.c + ch] = v;
    }

    /// Rows `[y0, y1)` as a borrowed band view (copy).
    pub fn rows(&self, y0: usize, y1: usize) -> ImageU8 {
        let y1 = y1.min(self.h);
        ImageU8 {
            h: y1 - y0,
            w: self.w,
            c: self.c,
            data: self.data[y0 * self.w * self.c..y1 * self.w * self.c]
                .to_vec(),
        }
    }

    pub fn to_f32(&self) -> ImageF32 {
        ImageF32 {
            h: self.h,
            w: self.w,
            c: self.c,
            data: self.data.iter().map(|&v| v as f32 / 255.0).collect(),
        }
    }
}

/// A float HWC image in [0, 1] (the PJRT runtime's format).
#[derive(Clone, Debug, PartialEq)]
pub struct ImageF32 {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl ImageF32 {
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        Self {
            h,
            w,
            c,
            data: vec![0.0; h * w * c],
        }
    }

    pub fn from_vec(h: usize, w: usize, c: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), h * w * c, "image buffer size mismatch");
        Self { h, w, c, data }
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> f32 {
        self.data[(y * self.w + x) * self.c + ch]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: f32) {
        self.data[(y * self.w + x) * self.c + ch] = v;
    }

    /// Quantize to u8 with round-half-up, clamped — matches
    /// `np.clip(np.round(x*255), 0, 255)` on the Python side.
    pub fn to_u8(&self) -> ImageU8 {
        ImageU8 {
            h: self.h,
            w: self.w,
            c: self.c,
            data: self
                .data
                .iter()
                .map(|&v| (v * 255.0).round().clamp(0.0, 255.0) as u8)
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u8_f32_roundtrip() {
        let mut im = ImageU8::new(2, 3, 3);
        im.set(1, 2, 0, 255);
        im.set(0, 0, 2, 128);
        let f = im.to_f32();
        assert!((f.get(1, 2, 0) - 1.0).abs() < 1e-6);
        let back = f.to_u8();
        assert_eq!(back, im);
    }

    #[test]
    fn rows_band_view() {
        let mut im = ImageU8::new(4, 2, 1);
        for y in 0..4 {
            im.set(y, 0, 0, y as u8);
        }
        let band = im.rows(1, 3);
        assert_eq!(band.h, 2);
        assert_eq!(band.get(0, 0, 0), 1);
        assert_eq!(band.get(1, 0, 0), 2);
    }

    #[test]
    fn rows_clamps_at_bottom() {
        let im = ImageU8::new(5, 2, 1);
        assert_eq!(im.rows(3, 99).h, 2);
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn from_vec_checks_len() {
        ImageU8::from_vec(2, 2, 1, vec![0; 5]);
    }
}
