//! Resampling: the x3 box downsample (LR degradation model, matching
//! `python/compile/data.downsample_x3`) and nearest-neighbour upsample
//! (the APBN anchor path).

use super::{ImageF32, ImageU8};

/// Box-filter x3 downsample of a float image; h and w must be
/// divisible by 3 (the caller crops beforehand).
pub fn box_downsample_x3(img: &ImageF32) -> ImageF32 {
    assert!(
        img.h % 3 == 0 && img.w % 3 == 0,
        "box_downsample_x3 needs h,w divisible by 3 (got {}x{})",
        img.h,
        img.w
    );
    let (oh, ow, c) = (img.h / 3, img.w / 3, img.c);
    let mut out = ImageF32::new(oh, ow, c);
    for y in 0..oh {
        for x in 0..ow {
            for ch in 0..c {
                let mut s = 0.0f32;
                for dy in 0..3 {
                    for dx in 0..3 {
                        s += img.get(3 * y + dy, 3 * x + dx, ch);
                    }
                }
                out.set(y, x, ch, s / 9.0);
            }
        }
    }
    out
}

/// Nearest-neighbour x`r` upsample of a u8 image — the anchor.
pub fn nearest_upsample(img: &ImageU8, r: usize) -> ImageU8 {
    let mut out = ImageU8::new(img.h * r, img.w * r, img.c);
    for y in 0..out.h {
        for x in 0..out.w {
            for ch in 0..img.c {
                out.set(y, x, ch, img.get(y / r, x / r, ch));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn box_mean_of_constant_is_constant() {
        let img = ImageF32::from_vec(3, 3, 1, vec![0.5; 9]);
        let d = box_downsample_x3(&img);
        assert_eq!((d.h, d.w), (1, 1));
        assert!((d.get(0, 0, 0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn box_mean_values() {
        let data: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let img = ImageF32::from_vec(3, 3, 1, data);
        let d = box_downsample_x3(&img);
        assert!((d.get(0, 0, 0) - 4.0).abs() < 1e-6); // mean of 0..8
    }

    #[test]
    fn nearest_replicates_pixels() {
        let img = ImageU8::from_vec(1, 2, 1, vec![7, 9]);
        let up = nearest_upsample(&img, 3);
        assert_eq!((up.h, up.w), (3, 6));
        for y in 0..3 {
            for x in 0..3 {
                assert_eq!(up.get(y, x, 0), 7);
                assert_eq!(up.get(y, x + 3, 0), 9);
            }
        }
    }

    #[test]
    #[should_panic(expected = "divisible by 3")]
    fn downsample_rejects_ragged() {
        box_downsample_x3(&ImageF32::new(4, 3, 1));
    }
}
