//! Resampling: the x3 box downsample (LR degradation model, matching
//! `python/compile/data.downsample_x3`), nearest-neighbour upsample
//! (the APBN anchor path), and the integer bilinear upsample (the
//! cheap degraded-quality path `RtPolicy::Degrade` falls back to).

use super::{ImageF32, ImageU8};

/// Box-filter x3 downsample of a float image; h and w must be
/// divisible by 3 (the caller crops beforehand).
pub fn box_downsample_x3(img: &ImageF32) -> ImageF32 {
    assert!(
        img.h % 3 == 0 && img.w % 3 == 0,
        "box_downsample_x3 needs h,w divisible by 3 (got {}x{})",
        img.h,
        img.w
    );
    let (oh, ow, c) = (img.h / 3, img.w / 3, img.c);
    let mut out = ImageF32::new(oh, ow, c);
    for y in 0..oh {
        for x in 0..ow {
            for ch in 0..c {
                let mut s = 0.0f32;
                for dy in 0..3 {
                    for dx in 0..3 {
                        s += img.get(3 * y + dy, 3 * x + dx, ch);
                    }
                }
                out.set(y, x, ch, s / 9.0);
            }
        }
    }
    out
}

/// Bilinear x`r` upsample of a u8 image in exact integer arithmetic —
/// the cheap fallback the serving tier downshifts to when a frame's
/// deadline is at risk (`RtPolicy::Degrade`).
///
/// Half-pixel-center mapping (`src = (dst + 0.5)/r - 0.5`), edges
/// clamped.  The source offset for output pixel `d` is the exact
/// rational `(2d + 1 - r) / 2r`, so the whole interpolation runs in
/// integers with denominator `(2r)^2` and round-half-up — bit-stable
/// across hosts, which the chaos tests rely on.
pub fn bilinear_upsample(img: &ImageU8, r: usize) -> ImageU8 {
    assert!(r >= 1, "bilinear_upsample needs r >= 1 (got {r})");
    let mut out = ImageU8::new(img.h * r, img.w * r, img.c);
    let d2 = (2 * r) as i64;
    // source index + fractional weight (numerator over 2r), clamped
    let coord = |dst: usize, n: usize| -> (usize, usize, i64) {
        let num = 2 * dst as i64 + 1 - r as i64;
        let mut i0 = num.div_euclid(d2);
        let mut f = num.rem_euclid(d2);
        if i0 < 0 {
            i0 = 0;
            f = 0;
        }
        let mut i1 = i0 as usize + 1;
        if i1 >= n {
            i1 = n - 1;
            if i0 as usize >= n - 1 {
                f = 0;
            }
        }
        (i0 as usize, i1, f)
    };
    let denom = d2 * d2;
    for y in 0..out.h {
        let (y0, y1, fy) = coord(y, img.h);
        for x in 0..out.w {
            let (x0, x1, fx) = coord(x, img.w);
            for ch in 0..img.c {
                let v00 = img.get(y0, x0, ch) as i64;
                let v01 = img.get(y0, x1, ch) as i64;
                let v10 = img.get(y1, x0, ch) as i64;
                let v11 = img.get(y1, x1, ch) as i64;
                let top = v00 * (d2 - fx) + v01 * fx;
                let bot = v10 * (d2 - fx) + v11 * fx;
                let sum = top * (d2 - fy) + bot * fy;
                let v = (sum + denom / 2) / denom;
                out.set(y, x, ch, v.clamp(0, 255) as u8);
            }
        }
    }
    out
}

/// Nearest-neighbour x`r` upsample of a u8 image — the anchor.
pub fn nearest_upsample(img: &ImageU8, r: usize) -> ImageU8 {
    let mut out = ImageU8::new(img.h * r, img.w * r, img.c);
    for y in 0..out.h {
        for x in 0..out.w {
            for ch in 0..img.c {
                out.set(y, x, ch, img.get(y / r, x / r, ch));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn box_mean_of_constant_is_constant() {
        let img = ImageF32::from_vec(3, 3, 1, vec![0.5; 9]);
        let d = box_downsample_x3(&img);
        assert_eq!((d.h, d.w), (1, 1));
        assert!((d.get(0, 0, 0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn box_mean_values() {
        let data: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let img = ImageF32::from_vec(3, 3, 1, data);
        let d = box_downsample_x3(&img);
        assert!((d.get(0, 0, 0) - 4.0).abs() < 1e-6); // mean of 0..8
    }

    #[test]
    fn nearest_replicates_pixels() {
        let img = ImageU8::from_vec(1, 2, 1, vec![7, 9]);
        let up = nearest_upsample(&img, 3);
        assert_eq!((up.h, up.w), (3, 6));
        for y in 0..3 {
            for x in 0..3 {
                assert_eq!(up.get(y, x, 0), 7);
                assert_eq!(up.get(y, x + 3, 0), 9);
            }
        }
    }

    #[test]
    #[should_panic(expected = "divisible by 3")]
    fn downsample_rejects_ragged() {
        box_downsample_x3(&ImageF32::new(4, 3, 1));
    }

    #[test]
    fn bilinear_constant_is_exact() {
        let img = ImageU8::from_vec(2, 2, 1, vec![42; 4]);
        for r in 1..=4 {
            let up = bilinear_upsample(&img, r);
            assert_eq!((up.h, up.w), (2 * r, 2 * r));
            assert!(up.data.iter().all(|&v| v == 42), "r={r}");
        }
    }

    #[test]
    fn bilinear_r1_is_identity() {
        let img = ImageU8::from_vec(2, 3, 2, (0..12).collect());
        assert_eq!(bilinear_upsample(&img, 1), img);
    }

    #[test]
    fn bilinear_interpolates_between_neighbours() {
        // 1x2 [0, 100] at x2: centers fall 1/4 and 3/4 between the
        // two sources -> exact quarter weights, round-half-up.
        let img = ImageU8::from_vec(1, 2, 1, vec![0, 100]);
        let up = bilinear_upsample(&img, 2);
        assert_eq!(up.data, vec![0, 25, 75, 100]);
    }

    #[test]
    fn bilinear_is_deterministic_and_edge_clamped() {
        let img = ImageU8::from_vec(3, 3, 1, (0..9).map(|i| i * 28).collect());
        let a = bilinear_upsample(&img, 3);
        let b = bilinear_upsample(&img, 3);
        assert_eq!(a, b);
        // corners replicate the corner sources (clamped mapping)
        assert_eq!(a.get(0, 0, 0), img.get(0, 0, 0));
        assert_eq!(a.get(8, 8, 0), img.get(2, 2, 0));
    }
}
