//! Synthetic scene / video generator — the workload source for the
//! serving pipeline and the benchmarks (the paper's target is a live
//! 640x360 video feed, which we simulate per DESIGN.md §4).
//!
//! Mirrors the Python corpus generators in spirit (gradients, periodic
//! texture, checkers, boxes, glyph strokes) plus temporal motion for
//! video: each frame advances a deterministic phase so consecutive
//! frames are correlated like real video.

use crate::util::Xoshiro256pp;

use super::ImageU8;

/// Deterministic procedural scene generator.
pub struct SceneGenerator {
    pub w: usize,
    pub h: usize,
    seed: u64,
}

impl SceneGenerator {
    pub fn new(w: usize, h: usize, seed: u64) -> Self {
        Self { w, h, seed }
    }

    /// The LR geometry of the paper (640x360).
    pub fn paper_lr(seed: u64) -> Self {
        Self::new(640, 360, seed)
    }

    /// Render frame `t` of the synthetic video.
    pub fn frame(&self, t: usize) -> ImageU8 {
        let mut rng = Xoshiro256pp::seed_from_u64(self.seed);
        let mut img = ImageU8::new(self.h, self.w, 3);
        // scene parameters fixed by seed; phase advances with t
        let n_waves = 2 + (rng.next_u32() % 3) as usize;
        let waves: Vec<(f64, f64, f64, f64, [f64; 3])> = (0..n_waves)
            .map(|_| {
                (
                    rng.uniform(0.01, 0.12),           // fx
                    rng.uniform(0.01, 0.12),           // fy
                    rng.uniform(0.0, std::f64::consts::TAU), // phase
                    rng.uniform(0.02, 0.2),            // speed
                    [
                        rng.uniform(0.2, 1.0),
                        rng.uniform(0.2, 1.0),
                        rng.uniform(0.2, 1.0),
                    ],
                )
            })
            .collect();
        let (bx, by) = (rng.uniform(0.0, 1.0), rng.uniform(0.0, 1.0));
        let box_col = [
            rng.uniform(0.0, 1.0),
            rng.uniform(0.0, 1.0),
            rng.uniform(0.0, 1.0),
        ];
        let box_w = self.w / 6 + 4;
        let box_h = self.h / 6 + 4;
        let vx = rng.uniform(0.5, 3.0);
        let vy = rng.uniform(0.2, 1.5);

        let tf = t as f64;
        for y in 0..self.h {
            for x in 0..self.w {
                let mut px = [0.45f64, 0.45, 0.45];
                for (fx, fy, ph, speed, col) in &waves {
                    let v = (std::f64::consts::TAU
                        * (fx * x as f64 + fy * y as f64)
                        + ph
                        + speed * tf)
                        .sin()
                        * 0.22;
                    for ch in 0..3 {
                        px[ch] += v * col[ch];
                    }
                }
                for (ch, p) in px.iter().enumerate() {
                    img.set(
                        y,
                        x,
                        ch,
                        (p.clamp(0.0, 1.0) * 255.0).round() as u8,
                    );
                }
            }
        }
        // a moving box (hard edges exercise the SR trunk)
        let bx0 = ((bx * self.w as f64 + vx * tf) as usize) % self.w;
        let by0 = ((by * self.h as f64 + vy * tf) as usize) % self.h;
        for dy in 0..box_h {
            let y = (by0 + dy) % self.h;
            for dx in 0..box_w {
                let x = (bx0 + dx) % self.w;
                for ch in 0..3 {
                    img.set(
                        y,
                        x,
                        ch,
                        (box_col[ch] * 255.0).round() as u8,
                    );
                }
            }
        }
        img
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_are_deterministic() {
        let g = SceneGenerator::new(32, 24, 9);
        assert_eq!(g.frame(3), g.frame(3));
    }

    #[test]
    fn consecutive_frames_differ_but_are_correlated() {
        let g = SceneGenerator::new(48, 32, 1);
        let a = g.frame(0);
        let b = g.frame(1);
        assert_ne!(a, b, "motion must change the frame");
        // correlated: mean abs diff small relative to full range
        let mad: f64 = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(&x, &y)| x.abs_diff(y) as f64)
            .sum::<f64>()
            / a.data.len() as f64;
        assert!(mad < 40.0, "frames uncorrelated (mad {mad})");
    }

    #[test]
    fn different_seeds_different_scenes() {
        let a = SceneGenerator::new(32, 24, 1).frame(0);
        let b = SceneGenerator::new(32, 24, 2).frame(0);
        assert_ne!(a, b);
    }

    #[test]
    fn paper_lr_geometry() {
        let g = SceneGenerator::paper_lr(0);
        assert_eq!((g.w, g.h), (640, 360));
    }
}
