//! Image quality metrics: MSE / PSNR (the paper's quality currency).

use super::{ImageF32, ImageU8};

/// Mean squared error between two float images.
pub fn mse(a: &ImageF32, b: &ImageF32) -> f64 {
    assert_eq!(
        (a.h, a.w, a.c),
        (b.h, b.w, b.c),
        "mse: shape mismatch"
    );
    let n = a.data.len() as f64;
    a.data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        / n
}

/// PSNR in dB for float images in [0, 1].
pub fn psnr(a: &ImageF32, b: &ImageF32) -> f64 {
    let m = mse(a, b);
    if m == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (1.0 / m).log10()
    }
}

/// PSNR in dB for u8 images (peak 255).
pub fn psnr_u8(a: &ImageU8, b: &ImageU8) -> f64 {
    assert_eq!((a.h, a.w, a.c), (b.h, b.w, b.c), "psnr_u8: shape mismatch");
    let n = a.data.len() as f64;
    let m = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        / n;
    if m == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (255.0 * 255.0 / m).log10()
    }
}

/// Max absolute per-pixel difference (u8) — used for bit-exactness
/// assertions with a human-readable failure mode.
pub fn max_abs_diff_u8(a: &ImageU8, b: &ImageU8) -> u8 {
    a.data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| x.abs_diff(y))
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_images_infinite_psnr() {
        let a = ImageF32::from_vec(1, 2, 1, vec![0.25, 0.5]);
        assert!(psnr(&a, &a).is_infinite());
    }

    #[test]
    fn known_mse() {
        let a = ImageF32::from_vec(1, 2, 1, vec![0.0, 0.0]);
        let b = ImageF32::from_vec(1, 2, 1, vec![0.1, 0.3]);
        // f32 storage of 0.1/0.3 is inexact; compare loosely
        assert!((mse(&a, &b) - (0.01 + 0.09) / 2.0).abs() < 1e-6);
    }

    #[test]
    fn psnr_u8_one_lsb_everywhere() {
        let a = ImageU8::from_vec(2, 2, 1, vec![10; 4]);
        let b = ImageU8::from_vec(2, 2, 1, vec![11; 4]);
        // MSE = 1 -> PSNR = 20*log10(255) = 48.13
        assert!((psnr_u8(&a, &b) - 48.130_8).abs() < 0.01);
        assert_eq!(max_abs_diff_u8(&a, &b), 1);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn mismatched_shapes_panic() {
        let a = ImageF32::new(1, 2, 1);
        let b = ImageF32::new(2, 1, 1);
        mse(&a, &b);
    }
}
