//! Binary PPM (P6) read/write — the repo's portable image format.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::ImageU8;

/// Write an RGB image as binary PPM (P6).
pub fn write_ppm(path: &Path, img: &ImageU8) -> Result<()> {
    if img.c != 3 {
        bail!("PPM requires 3 channels, image has {}", img.c);
    }
    let f = File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    write!(w, "P6\n{} {}\n255\n", img.w, img.h)?;
    w.write_all(&img.data)?;
    Ok(())
}

fn read_token(r: &mut impl Read) -> Result<String> {
    let mut tok = String::new();
    let mut byte = [0u8; 1];
    // skip whitespace and comments
    loop {
        r.read_exact(&mut byte)?;
        match byte[0] {
            b'#' => {
                // comment to end of line
                while byte[0] != b'\n' {
                    r.read_exact(&mut byte)?;
                }
            }
            b' ' | b'\t' | b'\r' | b'\n' => {}
            _ => break,
        }
    }
    tok.push(byte[0] as char);
    loop {
        r.read_exact(&mut byte)?;
        match byte[0] {
            b' ' | b'\t' | b'\r' | b'\n' => break,
            c => tok.push(c as char),
        }
    }
    Ok(tok)
}

/// Read a binary PPM (P6) into an RGB image.
pub fn read_ppm(path: &Path) -> Result<ImageU8> {
    let f = File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let magic = read_token(&mut r)?;
    if magic != "P6" {
        bail!("not a P6 PPM: magic {magic:?}");
    }
    let w: usize = read_token(&mut r)?.parse().context("PPM width")?;
    let h: usize = read_token(&mut r)?.parse().context("PPM height")?;
    let maxval: usize = read_token(&mut r)?.parse().context("PPM maxval")?;
    if maxval != 255 {
        bail!("unsupported PPM maxval {maxval}");
    }
    let mut data = vec![0u8; h * w * 3];
    r.read_exact(&mut data).context("PPM pixel data")?;
    Ok(ImageU8::from_vec(h, w, 3, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256pp;

    #[test]
    fn ppm_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let mut img = ImageU8::new(7, 9, 3);
        rng.fill_u8(&mut img.data);
        let dir = std::env::temp_dir().join("sr_accel_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.ppm");
        write_ppm(&path, &img).unwrap();
        let back = read_ppm(&path).unwrap();
        assert_eq!(back, img);
    }

    #[test]
    fn rejects_non_rgb() {
        let img = ImageU8::new(2, 2, 1);
        let path = std::env::temp_dir().join("bad.ppm");
        assert!(write_ppm(&path, &img).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("sr_accel_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad_magic.ppm");
        std::fs::write(&path, b"P5\n1 1\n255\nx").unwrap();
        assert!(read_ppm(&path).is_err());
    }

    #[test]
    fn parses_comments() {
        let dir = std::env::temp_dir().join("sr_accel_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("comment.ppm");
        std::fs::write(&path, b"P6\n# hello\n1 1\n255\nabc").unwrap();
        let img = read_ppm(&path).unwrap();
        assert_eq!((img.h, img.w), (1, 1));
        assert_eq!(img.data, b"abc");
    }
}
