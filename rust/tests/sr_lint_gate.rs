//! Self-check: the real tree passes `sr-lint` clean.
//!
//! This is the same walk the `sr-lint` binary performs (src, benches,
//! tests), run from `cargo test` so the static-analysis gate cannot
//! silently drift from CI: a new naked `unwrap()` in `coordinator/`
//! or a stray `unsafe` outside the kernel allowlist fails the normal
//! test suite, not just the dedicated lint job.

use sr_accel::lint::{default_roots, lint_tree};

#[test]
fn tree_is_lint_clean() {
    let report = lint_tree(&default_roots()).expect("tree walk failed");
    let rendered: Vec<String> =
        report.diagnostics.iter().map(|d| d.to_string()).collect();
    assert!(
        rendered.is_empty(),
        "sr-lint found {} violation(s):\n{}",
        rendered.len(),
        rendered.join("\n")
    );
    // A broken `default_roots` that walks nothing must not masquerade
    // as a clean tree; the crate has far more than 40 .rs files.
    assert!(
        report.files >= 40,
        "suspiciously few files scanned: {}",
        report.files
    );
}
