//! Miri-sized contract tests for the unsafe kernel surface and the
//! threaded coordinator (§Static analysis & sanitizers).
//!
//! CI runs this file under `cargo miri test` (and the normal suite
//! runs it natively, where it doubles as a smoke test).  Under Miri,
//! runtime feature detection reports no SIMD, so `Isa::select`
//! resolves to the scalar kernel: the dispatch plumbing, the
//! `debug_assert_strip_contract` precondition layer, every slice
//! split in the strip walk, and the coordinator's channels and locks
//! all execute under the interpreter's UB and data-race checkers.
//! Geometry is deliberately tiny — Miri is ~3 orders of magnitude
//! slower than native.

use sr_accel::config::{RestartPolicy, ShardPlan};
use sr_accel::coordinator::{
    run_pipeline, Engine, EngineFactory, FaultPlan, Int8Engine,
    PipelineConfig,
};
use sr_accel::model::{
    PreparedLayer, PreparedModel, QuantLayer, QuantModel, Scratch, Tensor,
};
use sr_accel::reference::conv::{conv3x3_final_impl, conv3x3_relu_impl};
use sr_accel::reference;
use sr_accel::util::fixed::{clamp_u8, FixedMul};
use sr_accel::util::Xoshiro256pp;

fn small_layer(cin: usize, cout: usize, relu: bool, seed: u64) -> QuantLayer {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    QuantLayer {
        cin,
        cout,
        relu,
        s_in: 1.0 / 255.0,
        s_w: 0.01,
        s_out: 1.0 / 255.0,
        m: FixedMul::from_real(0.05),
        bias: (0..cout)
            .map(|_| rng.range_u64(0, 200) as i32 - 100)
            .collect(),
        w: (0..9 * cin * cout)
            .map(|_| (rng.range_u64(0, 255) as i64 - 128) as i8)
            .collect(),
    }
}

fn small_map(h: usize, w: usize, c: usize, seed: u64) -> Tensor<u8> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut t = Tensor::new(h, w, c);
    rng.fill_u8(&mut t.data);
    t
}

/// Independent direct SAME 3x3 conv — no packing, no scratch, no
/// shared code with the kernels under test.
fn naive_conv3x3(x: &Tensor<u8>, l: &QuantLayer) -> (Vec<u8>, Vec<i32>) {
    let mut out_u8 = vec![0u8; x.h * x.w * l.cout];
    let mut out_i32 = vec![0i32; x.h * x.w * l.cout];
    for y in 0..x.h {
        for xx in 0..x.w {
            for co in 0..l.cout {
                let mut acc: i32 = l.bias[co];
                for dr in 0..3usize {
                    for dc in 0..3usize {
                        let sy = y as isize + dr as isize - 1;
                        let sx = xx as isize + dc as isize - 1;
                        if sy < 0
                            || sy >= x.h as isize
                            || sx < 0
                            || sx >= x.w as isize
                        {
                            continue;
                        }
                        for ci in 0..l.cin {
                            acc += x.get(sy as usize, sx as usize, ci)
                                as i32
                                * l.weight(dr, dc, ci, co) as i32;
                        }
                    }
                }
                let q = l.m.apply(acc as i64);
                out_u8[(y * x.w + xx) * l.cout + co] = clamp_u8(q);
                out_i32[(y * x.w + xx) * l.cout + co] = q as i32;
            }
        }
    }
    (out_u8, out_i32)
}

#[test]
fn strip_kernel_matches_naive_oracle() {
    // Both dispatch routes (auto — scalar under Miri — and forced
    // scalar), both epilogues, widths straddling the strip width so
    // the masked-tail path runs under the interpreter too.
    let mut scratch = Scratch::new();
    for &(h, w, cin, cout) in
        &[(3usize, 5usize, 3usize, 4usize), (2, 7, 1, 9), (4, 3, 5, 8)]
    {
        let seed = (h * 131 + w * 17 + cin * 5 + cout) as u64;
        let x = small_map(h, w, cin, seed);
        for relu in [true, false] {
            let l = small_layer(cin, cout, relu, seed ^ 0x9E37);
            let pl = PreparedLayer::new(&l);
            let (want_u8, want_i32) = naive_conv3x3(&x, &l);
            for force_scalar in [false, true] {
                if relu {
                    let y =
                        conv3x3_relu_impl(&x, &pl, &mut scratch, force_scalar);
                    assert_eq!(
                        y.data, want_u8,
                        "relu {h}x{w} {cin}->{cout} scalar={force_scalar}"
                    );
                    scratch.recycle_u8(y);
                } else {
                    let y = conv3x3_final_impl(
                        &x,
                        &pl,
                        &mut scratch,
                        force_scalar,
                    );
                    assert_eq!(
                        y.data, want_i32,
                        "final {h}x{w} {cin}->{cout} scalar={force_scalar}"
                    );
                    scratch.recycle_i32(y);
                }
            }
        }
    }
}

#[test]
fn whole_model_forward_is_deterministic_and_prepared_exact() {
    // The prepared fast path (packed weights + scratch reuse) must be
    // bit-identical to the one-shot wrapper across repeated frames —
    // under Miri this walks every weight-packing index computation.
    let qm = QuantModel::test_model(3, 3, 4, 2, 7);
    let pm = PreparedModel::new(&qm);
    let mut scratch = Scratch::new();
    for frame_seed in 0..2u64 {
        let x = small_map(6, 7, 3, 40 + frame_seed);
        let want = reference::forward_int(&x, &qm);
        let got = reference::forward_int_prepared(&x, &pm, &mut scratch);
        assert_eq!(got.data, want.data, "frame {frame_seed}");
        assert_eq!((got.h, got.w), (x.h * 2, x.w * 2));
    }
}

#[test]
fn threaded_pipeline_is_exact_and_race_free() {
    // Tiny end-to-end serve: 2 workers sharing the work queue, the
    // collector reassembling in order — Miri's data-race detector
    // covers the channel + mutex protocol; output equality covers the
    // serving math.  Native runs get a fast extra e2e smoke test.
    let factories = |n: usize| -> Vec<EngineFactory> {
        (0..n)
            .map(|_| {
                Box::new(move || {
                    Ok(Box::new(Int8Engine::new(QuantModel::test_model(
                        2, 3, 4, 2, 11,
                    ))) as Box<dyn Engine>)
                }) as EngineFactory
            })
            .collect()
    };
    let cfg = |workers: usize| PipelineConfig {
        frames: 3,
        queue_depth: 2,
        workers,
        lr_w: 10,
        lr_h: 8,
        seed: 13,
        source_fps: None,
        scale: 2,
        shard: ShardPlan::whole_frame(),
        model_layers: 2,
        restart: RestartPolicy::none(),
        stall_budget_ms: None,
        inject: FaultPlan::default(),
    };
    let mut one = Vec::new();
    run_pipeline(&cfg(1), factories(1), |_, hr| one.push(hr.clone()))
        .unwrap();
    let mut two = Vec::new();
    run_pipeline(&cfg(2), factories(2), |_, hr| two.push(hr.clone()))
        .unwrap();
    assert_eq!(one.len(), 3);
    assert_eq!(one, two, "worker count must not change served frames");
}
