//! Property tests of the fusion schedulers (the coordinator invariants
//! of DESIGN.md §5), using the in-repo quickcheck substrate.
//!
//! The central claim of the paper — tilted fusion loses nothing
//! horizontally — is checked over randomized geometry: any band height,
//! image width, tile width, layer count and channel mix.

use sr_accel::config::AcceleratorConfig;
use sr_accel::fusion::{
    BlockConvScheduler, ClassicalScheduler, FusionScheduler,
    LayerByLayerScheduler, TiltedScheduler,
};
use sr_accel::model::{QuantModel, Tensor};
use sr_accel::reference;
use sr_accel::util::quickcheck::{check, shrink_dims, Config};
use sr_accel::util::Xoshiro256pp;

fn rand_band(h: usize, w: usize, seed: u64) -> Tensor<u8> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut t = Tensor::new(h, w, 3);
    rng.fill_u8(&mut t.data);
    t
}

/// dims = [rows, width, tile_cols, n_layers, c_mid, seed]
fn gen_dims(rng: &mut Xoshiro256pp) -> Vec<usize> {
    vec![
        rng.range_usize(3, 14),  // rows
        rng.range_usize(4, 40),  // width
        rng.range_usize(2, 12),  // tile_cols
        rng.range_usize(1, 6),   // layers
        rng.range_usize(1, 7),   // mid channels
        rng.range_usize(0, 10_000),
    ]
}

#[test]
fn prop_tilted_band_bit_exact_any_geometry() {
    let cfg = Config {
        cases: 40,
        seed: 0x7151,
        max_shrink_iters: 60,
    };
    check(
        &cfg,
        gen_dims,
        |d| {
            let (rows, width, tile_cols, layers, c_mid, seed) =
                (d[0], d[1], d[2], d[3], d[4], d[5] as u64);
            let qm = QuantModel::test_model(layers.max(1), 3, c_mid.max(1), 3, seed);
            let band = rand_band(rows, width, seed + 1);
            let acc = AcceleratorConfig {
                tile_rows: rows,
                tile_cols,
                ..AcceleratorConfig::paper()
            };
            let (hr, _) =
                TiltedScheduler::default().run_band(&band, &qm, &acc);
            let want = reference::forward_int(&band, &qm);
            if hr.data != want.data {
                return Err(format!(
                    "tilted differs from reference at {rows}x{width}, C={tile_cols}, L={layers}"
                ));
            }
            Ok(())
        },
        |d| shrink_dims(d, &[3, 4, 2, 1, 1, 0]),
    );
}

#[test]
fn prop_classical_recompute_bit_exact() {
    let cfg = Config {
        cases: 20,
        seed: 0xC1A5,
        max_shrink_iters: 40,
    };
    check(
        &cfg,
        gen_dims,
        |d| {
            let (rows, width, tile, layers, c_mid, seed) =
                (d[0], d[1], d[2].max(3), d[3], d[4], d[5] as u64);
            let qm = QuantModel::test_model(layers.max(1), 3, c_mid.max(1), 3, seed);
            let frame = rand_band(rows, width, seed + 2);
            let sched = ClassicalScheduler {
                tile_rows: tile,
                tile_cols: tile,
            };
            let res = sched.run_frame(&frame, &qm, &AcceleratorConfig::paper());
            let want = reference::forward_int(&frame, &qm);
            if res.hr.data != want.data {
                return Err("classical recompute differs".into());
            }
            Ok(())
        },
        |d| shrink_dims(d, &[3, 4, 3, 1, 1, 0]),
    );
}

#[test]
fn prop_layer_by_layer_bit_exact() {
    let cfg = Config {
        cases: 15,
        seed: 0x1B1,
        max_shrink_iters: 30,
    };
    check(
        &cfg,
        gen_dims,
        |d| {
            let qm = QuantModel::test_model(d[3].max(1), 3, d[4].max(1), 3, d[5] as u64);
            let frame = rand_band(d[0], d[1], d[5] as u64 + 3);
            let res = LayerByLayerScheduler
                .run_frame(&frame, &qm, &AcceleratorConfig::paper());
            if res.hr.data != reference::forward_int(&frame, &qm).data {
                return Err("layer-by-layer differs".into());
            }
            Ok(())
        },
        |d| shrink_dims(d, &[3, 4, 2, 1, 1, 0]),
    );
}

#[test]
fn prop_all_exact_schedulers_agree_with_each_other() {
    // tilted (per band == whole frame here: one band) == classical ==
    // layer-by-layer, for frames that fit a single band
    let cfg = Config {
        cases: 12,
        seed: 0xA9,
        max_shrink_iters: 30,
    };
    check(
        &cfg,
        gen_dims,
        |d| {
            let (rows, width) = (d[0], d[1]);
            let qm = QuantModel::test_model(d[3].max(1), 3, d[4].max(1), 3, d[5] as u64);
            let frame = rand_band(rows, width, d[5] as u64 + 9);
            let acc = AcceleratorConfig {
                tile_rows: rows, // one band
                tile_cols: d[2],
                ..AcceleratorConfig::paper()
            };
            let a = TiltedScheduler::default().run_frame(&frame, &qm, &acc);
            let b = ClassicalScheduler::default().run_frame(&frame, &qm, &acc);
            let c = LayerByLayerScheduler.run_frame(&frame, &qm, &acc);
            if a.hr.data != b.hr.data || b.hr.data != c.hr.data {
                return Err("exact schedulers disagree".into());
            }
            Ok(())
        },
        |d| shrink_dims(d, &[3, 4, 2, 1, 1, 0]),
    );
}

#[test]
fn tilted_dram_traffic_is_io_only_and_smallest() {
    let qm = QuantModel::test_model(4, 3, 8, 3, 1);
    let frame = rand_band(24, 32, 5);
    let acc = AcceleratorConfig {
        tile_rows: 12,
        tile_cols: 8,
        ..AcceleratorConfig::paper()
    };
    let tilted = TiltedScheduler::default().run_frame(&frame, &qm, &acc);
    let lbl = LayerByLayerScheduler.run_frame(&frame, &qm, &acc);
    let classical =
        ClassicalScheduler { tile_rows: 12, tile_cols: 8 }
            .run_frame(&frame, &qm, &acc);
    assert!(
        tilted.stats.dram_total_bytes() < lbl.stats.dram_total_bytes(),
        "tilted must beat layer-by-layer on DRAM"
    );
    assert!(
        tilted.stats.dram_total_bytes()
            <= classical.stats.dram_total_bytes(),
        "tilted must not exceed classical (halo re-reads)"
    );
    // tilted traffic = input + weights + output exactly
    let expect = frame.byte_len() as u64
        + (qm.weight_bytes() + qm.bias_bytes()) as u64
        + (frame.h * 3 * frame.w * 3 * 3) as u64;
    assert_eq!(tilted.stats.dram_total_bytes(), expect);
}

#[test]
fn block_conv_loss_shrinks_with_tile_size() {
    use sr_accel::image::{psnr_u8, ImageU8};
    let qm = QuantModel::test_model(4, 3, 8, 3, 2);
    let frame = rand_band(24, 48, 6);
    let want = reference::forward_int(&frame, &qm);
    let to_img = |t: &Tensor<u8>| {
        ImageU8::from_vec(t.h, t.w, t.c, t.data.clone())
    };
    let mut prev_psnr = -1.0;
    for tile in [4, 8, 24] {
        let res = BlockConvScheduler {
            tile_rows: tile,
            tile_cols: tile,
        }
        .run_frame(&frame, &qm, &AcceleratorConfig::paper());
        let p = psnr_u8(&to_img(&res.hr), &to_img(&want));
        assert!(
            p >= prev_psnr,
            "block-conv PSNR should not fall as tiles grow: {p} after {prev_psnr}"
        );
        prev_psnr = p;
    }
}

#[test]
fn tilted_cycle_exact_and_analytic_agree_on_stats() {
    let qm = QuantModel::test_model(3, 3, 6, 3, 7);
    let band = rand_band(10, 24, 8);
    let acc = AcceleratorConfig {
        tile_rows: 10,
        tile_cols: 4,
        ..AcceleratorConfig::paper()
    };
    let (ha, sa) = TiltedScheduler::default().run_band(&band, &qm, &acc);
    let (hc, sc) = TiltedScheduler::cycle_exact().run_band(&band, &qm, &acc);
    assert_eq!(ha.data, hc.data);
    assert_eq!(sa.compute_cycles, sc.compute_cycles);
    assert_eq!(sa.mac_ops, sc.mac_ops);
    assert_eq!(sa.mac_slots, sc.mac_slots);
}
