//! Property tests of the band-sharded serving pipeline: sharding a
//! frame across workers must never change the pixels.
//!
//! Two equivalence regimes, both exercised over randomized geometry
//! with the in-repo quickcheck substrate:
//!
//! * `HaloPolicy::Exact` — band-sharded output is **bit-identical** to
//!   single-worker whole-frame inference, for any band height, worker
//!   count and frame geometry (each band carries a halo of the model's
//!   conv depth, so every cropped output row has its full receptive
//!   field);
//! * `HaloPolicy::None` — band-sharded output reproduces the *chip's*
//!   zero-padded band semantics, i.e. exactly what the tilted-fusion
//!   scheduler produces for the whole frame.

use sr_accel::config::{
    AcceleratorConfig, HaloPolicy, RestartPolicy, ShardPlan, ShardStrategy,
    WorkerAffinity,
};
use sr_accel::coordinator::{
    run_pipeline, Engine, EngineFactory, FaultPlan, Int8Engine,
    PipelineConfig, PipelineReport, SimEngine,
};
use sr_accel::fusion::{FusionScheduler, TiltedScheduler};
use sr_accel::image::{ImageU8, SceneGenerator};
use sr_accel::model::{QuantModel, Tensor};
use sr_accel::util::quickcheck::{check, shrink_dims, Config};

fn int8_factories(
    n: usize,
    layers: usize,
    c_mid: usize,
    seed: u64,
) -> Vec<EngineFactory> {
    (0..n)
        .map(|_| {
            Box::new(move || {
                Ok(Box::new(Int8Engine::new(QuantModel::test_model(
                    layers, 3, c_mid, 3, seed,
                ))) as Box<dyn Engine>)
            }) as EngineFactory
        })
        .collect()
}

fn base_cfg(
    lr_w: usize,
    lr_h: usize,
    frames: usize,
    model_layers: usize,
) -> PipelineConfig {
    PipelineConfig {
        frames,
        queue_depth: 2,
        workers: 1,
        lr_w,
        lr_h,
        seed: 11,
        source_fps: None,
        scale: 3,
        shard: ShardPlan::whole_frame(),
        model_layers,
        restart: RestartPolicy::none(),
        stall_budget_ms: None,
        inject: FaultPlan::default(),
    }
}

fn run(
    cfg: &PipelineConfig,
    factories: Vec<EngineFactory>,
) -> (Vec<ImageU8>, PipelineReport) {
    let mut out = Vec::new();
    let rep = run_pipeline(cfg, factories, |_, hr| out.push(hr.clone()))
        .expect("pipeline run failed");
    (out, rep)
}

/// The tentpole property: band-sharded serving with exact halos is
/// bit-identical to single-worker whole-frame serving, across random
/// geometries, band heights, worker counts and models.
#[test]
fn prop_band_sharded_bit_identical_to_whole_frame() {
    let cfg = Config {
        cases: 16,
        seed: 0x5AAD,
        max_shrink_iters: 40,
    };
    check(
        &cfg,
        |rng| {
            vec![
                rng.range_usize(6, 40),  // lr_w
                rng.range_usize(4, 32),  // lr_h
                rng.range_usize(1, 12),  // band_rows
                rng.range_usize(1, 4),   // workers
                rng.range_usize(1, 4),   // model layers
                rng.range_usize(1, 6),   // mid channels
                rng.range_usize(0, 999), // model seed
            ]
        },
        |d| {
            let (w, h, band_rows, workers, layers, c_mid) =
                (d[0], d[1], d[2], d[3], d[4].max(1), d[5].max(1));
            let seed = d[6] as u64;
            let (whole, _) = run(
                &base_cfg(w, h, 3, layers),
                int8_factories(1, layers, c_mid, seed),
            );
            let sharded_cfg = PipelineConfig {
                workers,
                shard: ShardPlan::row_bands(band_rows, HaloPolicy::Exact),
                ..base_cfg(w, h, 3, layers)
            };
            let (sharded, rep) = run(
                &sharded_cfg,
                int8_factories(workers, layers, c_mid, seed),
            );
            if whole.len() != sharded.len() {
                return Err(format!(
                    "frame count {} != {}",
                    sharded.len(),
                    whole.len()
                ));
            }
            if whole != sharded {
                return Err(format!(
                    "band-sharded differs from whole-frame at {w}x{h}, \
                     band_rows={band_rows}, workers={workers}, L={layers}"
                ));
            }
            if rep.frames != 3 {
                return Err(format!("report frames {}", rep.frames));
            }
            Ok(())
        },
        |d| shrink_dims(d, &[6, 4, 1, 1, 1, 1, 0]),
    );
}

/// Acceptance pin: identical output for >= 3 explicit worker counts,
/// under both dispatch affinities.
#[test]
fn band_sharded_identical_across_worker_counts_and_affinities() {
    let (layers, c_mid, seed) = (3, 5, 21u64);
    let (whole, _) = run(
        &base_cfg(33, 26, 6, layers),
        int8_factories(1, layers, c_mid, seed),
    );
    assert_eq!(whole.len(), 6);
    for workers in [1, 2, 3, 4] {
        for affinity in [WorkerAffinity::Any, WorkerAffinity::BandModulo] {
            let cfg = PipelineConfig {
                workers,
                shard: ShardPlan {
                    strategy: ShardStrategy::RowBands,
                    band_rows: 5,
                    halo: HaloPolicy::Exact,
                    affinity,
                },
                ..base_cfg(33, 26, 6, layers)
            };
            let (got, rep) =
                run(&cfg, int8_factories(workers, layers, c_mid, seed));
            assert_eq!(
                got, whole,
                "output changed: workers={workers} affinity={affinity:?}"
            );
            assert_eq!(rep.workers, workers);
        }
    }
}

/// With no halo, serving-level band sharding reproduces the *chip's*
/// band semantics: the stitched frame equals what the tilted-fusion
/// scheduler produces (zero-padded seams and all).
#[test]
fn no_halo_band_sharding_matches_tilted_scheduler() {
    let (layers, c_mid, seed) = (2, 4, 5u64);
    let qm = QuantModel::test_model(layers, 3, c_mid, 3, seed);
    let cfg = PipelineConfig {
        workers: 2,
        shard: ShardPlan::row_bands(6, HaloPolicy::None),
        ..base_cfg(16, 15, 3, layers)
    };
    let (got, _) = run(&cfg, int8_factories(2, layers, c_mid, seed));
    let acc = AcceleratorConfig {
        tile_rows: 6, // same band split as the serving plan
        tile_cols: 4,
        ..AcceleratorConfig::paper()
    };
    let gen = SceneGenerator::new(16, 15, 11);
    for (i, hr) in got.iter().enumerate() {
        let img = gen.frame(i);
        let frame = Tensor::from_vec(img.h, img.w, img.c, img.data);
        let want = TiltedScheduler::default().run_frame(&frame, &qm, &acc);
        assert_eq!(hr.data, want.hr.data, "frame {i}");
    }
}

/// Band-sharding the *simulator* engine at its own tile_rows
/// granularity preserves chip semantics exactly, and the pipeline
/// merges per-band RunStats into per-frame hardware reports whose
/// compute cycles match the monolithic run.
#[test]
fn sim_engine_band_sharding_preserves_output_and_merges_stats() {
    let qm = QuantModel::test_model(2, 3, 4, 3, 9);
    let acc = AcceleratorConfig {
        tile_rows: 6,
        tile_cols: 4,
        ..AcceleratorConfig::paper()
    };
    let sim_factories = |n: usize| -> Vec<EngineFactory> {
        (0..n)
            .map(|_| {
                let qm = qm.clone();
                let acc = acc.clone();
                Box::new(move || {
                    // clone *inside*: the supervisor may call the
                    // factory again after a restart
                    Ok(Box::new(SimEngine::new(qm.clone(), acc.clone()))
                        as Box<dyn Engine>)
                }) as EngineFactory
            })
            .collect()
    };
    let mono_cfg = base_cfg(20, 18, 4, 2);
    let (whole, mono_rep) = run(&mono_cfg, sim_factories(1));
    let sharded_cfg = PipelineConfig {
        workers: 3,
        // 18 rows / 6-row bands == the simulator's own band split, so
        // zero-padded seams land in the same places
        shard: ShardPlan::row_bands(6, HaloPolicy::None),
        ..base_cfg(20, 18, 4, 2)
    };
    let (sharded, rep) = run(&sharded_cfg, sim_factories(3));
    assert_eq!(sharded, whole, "sim band sharding changed pixels");

    let hw = rep.hw.as_ref().expect("sim engine must report merged stats");
    let mono_hw = mono_rep.hw.as_ref().unwrap();
    // same bands -> same compute work and tile count, just sharded
    assert_eq!(hw.compute_cycles, mono_hw.compute_cycles);
    assert_eq!(hw.tiles, mono_hw.tiles);
    assert!(hw.compute_cycles > 0);
    assert!(rep.render().contains("hw:"));
}

/// Degenerate plans stay well-formed: a band taller than the frame, a
/// one-row frame, and band_rows=0 (auto whole-height) all reduce to
/// whole-frame behaviour.
#[test]
fn degenerate_band_plans_match_whole_frame() {
    let (layers, c_mid, seed) = (2, 4, 3u64);
    for (w, h, band_rows) in [(12, 5, 99), (9, 1, 3), (10, 7, 0)] {
        let (whole, _) = run(
            &base_cfg(w, h, 2, layers),
            int8_factories(1, layers, c_mid, seed),
        );
        let cfg = PipelineConfig {
            workers: 2,
            shard: ShardPlan::row_bands(band_rows, HaloPolicy::Exact),
            ..base_cfg(w, h, 2, layers)
        };
        let (got, _) = run(&cfg, int8_factories(2, layers, c_mid, seed));
        assert_eq!(got, whole, "{w}x{h} band_rows={band_rows}");
    }
}
