//! Cross-language golden-vector tests: the Rust engines against the
//! Python executable spec (`python/compile/quant.py` / the JAX model).
//!
//! These are the strongest correctness signals in the repo:
//! * the integer engine must be **bit-exact** against `quant.forward_int`
//!   including per-layer FNV checksums;
//! * the PJRT runtime executing the AOT HLO must match the JAX float
//!   model to float tolerance (requires `--features pjrt`).
//!
//! All cases need the `make artifacts` bundle; on a bare checkout they
//! **skip** with a message instead of failing, so `cargo test` stays
//! green without Python in the loop.

use sr_accel::image::{psnr, ImageF32};
use sr_accel::model::load_apbnw;
use sr_accel::reference;
use sr_accel::runtime::{
    artifacts_available, artifacts_dir, load_golden_float, load_golden_quant,
};
use sr_accel::util::fnv1a64;

/// Skip (return early, with a note on stderr) when the AOT artifact
/// bundle is absent.
macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!(
                "SKIP: artifacts missing at {} — run `make artifacts`",
                artifacts_dir().display()
            );
            return;
        }
    };
}

#[test]
fn int8_engine_bit_exact_vs_python() {
    require_artifacts!();
    let dir = artifacts_dir();
    let qm = load_apbnw(&dir.join("weights.apbnw")).unwrap();
    let golden = load_golden_quant(&dir.join("golden_quant.bin")).unwrap();

    let got = reference::forward_int(&golden.input, &qm);
    assert_eq!(
        (got.h, got.w, got.c),
        (golden.output.h, golden.output.w, golden.output.c)
    );
    assert_eq!(
        got.data, golden.output.data,
        "integer engine diverged from quant.py"
    );
}

#[test]
fn int8_engine_per_layer_checksums_match() {
    require_artifacts!();
    let dir = artifacts_dir();
    let qm = load_apbnw(&dir.join("weights.apbnw")).unwrap();
    let golden = load_golden_quant(&dir.join("golden_quant.bin")).unwrap();

    let (layer_outs, pre) = reference::forward_layers(&golden.input, &qm);
    assert_eq!(
        golden.layer_checksums.len(),
        layer_outs.len() + 1,
        "checksum count"
    );
    for (i, t) in layer_outs.iter().enumerate() {
        assert_eq!(
            fnv1a64(t.as_bytes()),
            golden.layer_checksums[i],
            "layer {i} checksum mismatch"
        );
    }
    assert_eq!(
        fnv1a64(&pre.to_le_bytes()),
        *golden.layer_checksums.last().unwrap(),
        "final (pre-residual) layer checksum mismatch"
    );
}

#[test]
fn quantized_engine_close_to_float_model() {
    require_artifacts!();
    // end-to-end dequantization quality: int8 output vs float golden
    let dir = artifacts_dir();
    let qm = load_apbnw(&dir.join("weights.apbnw")).unwrap();
    let gf = load_golden_float(&dir.join("golden_float.bin")).unwrap();
    let lr_u8 = gf.input.to_u8();
    let got = reference::upscale(&lr_u8, &qm);
    let got_f = got.to_f32();
    let p = psnr(
        &got_f,
        &ImageF32::from_vec(
            gf.output.h,
            gf.output.w,
            gf.output.c,
            gf.output.data.clone(),
        ),
    );
    assert!(p > 40.0, "int8 vs float model PSNR too low: {p:.1} dB");
}

#[cfg(feature = "pjrt")]
mod pjrt_goldens {
    use super::*;
    use sr_accel::runtime::{Executor, Manifest};

    #[test]
    fn pjrt_tile_executor_matches_jax_float_model() {
        require_artifacts!();
        let dir = artifacts_dir();
        let manifest = Manifest::load(&dir).unwrap();
        let (in_shape, out_shape) =
            manifest.shapes("apbn_tile.hlo.txt").unwrap();
        let exe = Executor::load(
            &dir.join("apbn_tile.hlo.txt"),
            in_shape,
            out_shape,
        )
        .unwrap();
        let golden =
            load_golden_float(&dir.join("golden_float.bin")).unwrap();
        assert_eq!(
            (golden.input.h, golden.input.w, golden.input.c),
            in_shape,
            "golden float shape must match the tile artifact"
        );
        let out = exe.run(&golden.input).unwrap();
        let max_diff = out
            .data
            .iter()
            .zip(&golden.output.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_diff < 1e-4,
            "PJRT output diverged from JAX: max diff {max_diff}"
        );
    }

    #[test]
    fn pjrt_band_artifact_contains_pallas_lowering() {
        require_artifacts!();
        // the band artifact is lowered through the Pallas kernel path;
        // it must compile and run on the CPU client (interpret-mode
        // lowering)
        let dir = artifacts_dir();
        let manifest = Manifest::load(&dir).unwrap();
        let (in_shape, out_shape) =
            manifest.shapes("apbn_band.hlo.txt").unwrap();
        assert_eq!(in_shape, (60, 640, 3));
        let exe = Executor::load(
            &dir.join("apbn_band.hlo.txt"),
            in_shape,
            out_shape,
        )
        .unwrap();
        // feed a mid-gray band; output must be plausible (range kept)
        let band = ImageF32::from_vec(60, 640, 3, vec![0.5; 60 * 640 * 3]);
        let out = exe.run(&band).unwrap();
        assert_eq!((out.h, out.w, out.c), (180, 1920, 3));
        assert!(out.data.iter().all(|v| (0.0..=1.0).contains(v)));
    }
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_goldens_skipped_without_feature() {
    eprintln!(
        "SKIP: PJRT golden tests require `cargo test --features pjrt` \
         (and a real xla runtime in place of vendor/xla)"
    );
}
