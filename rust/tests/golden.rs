//! Cross-language golden-vector tests: the Rust engines against the
//! Python executable spec (`python/compile/quant.py` / the JAX model).
//!
//! These are the strongest correctness signals in the repo:
//! * the integer engine must be **bit-exact** against `quant.forward_int`
//!   including per-layer FNV checksums;
//! * the PJRT runtime executing the AOT HLO must match the JAX float
//!   model to float tolerance.
//!
//! Requires `make artifacts`.

use std::path::PathBuf;

use sr_accel::image::{psnr, ImageF32};
use sr_accel::model::load_apbnw;
use sr_accel::reference;
use sr_accel::runtime::{
    artifacts_dir, load_golden_float, load_golden_quant, Executor, Manifest,
};
use sr_accel::util::fnv1a64;

fn need(path: PathBuf) -> PathBuf {
    assert!(
        path.exists(),
        "{} missing — run `make artifacts` first",
        path.display()
    );
    path
}

#[test]
fn int8_engine_bit_exact_vs_python() {
    let dir = artifacts_dir();
    let qm = load_apbnw(&need(dir.join("weights.apbnw"))).unwrap();
    let golden = load_golden_quant(&need(dir.join("golden_quant.bin"))).unwrap();

    let got = reference::forward_int(&golden.input, &qm);
    assert_eq!(
        (got.h, got.w, got.c),
        (golden.output.h, golden.output.w, golden.output.c)
    );
    assert_eq!(
        got.data, golden.output.data,
        "integer engine diverged from quant.py"
    );
}

#[test]
fn int8_engine_per_layer_checksums_match() {
    let dir = artifacts_dir();
    let qm = load_apbnw(&need(dir.join("weights.apbnw"))).unwrap();
    let golden = load_golden_quant(&need(dir.join("golden_quant.bin"))).unwrap();

    let (layer_outs, pre) = reference::forward_layers(&golden.input, &qm);
    assert_eq!(
        golden.layer_checksums.len(),
        layer_outs.len() + 1,
        "checksum count"
    );
    for (i, t) in layer_outs.iter().enumerate() {
        assert_eq!(
            fnv1a64(t.as_bytes()),
            golden.layer_checksums[i],
            "layer {i} checksum mismatch"
        );
    }
    assert_eq!(
        fnv1a64(&pre.to_le_bytes()),
        *golden.layer_checksums.last().unwrap(),
        "final (pre-residual) layer checksum mismatch"
    );
}

#[test]
fn pjrt_tile_executor_matches_jax_float_model() {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir).unwrap();
    let (in_shape, out_shape) =
        manifest.shapes("apbn_tile.hlo.txt").unwrap();
    let exe = Executor::load(
        &need(dir.join("apbn_tile.hlo.txt")),
        in_shape,
        out_shape,
    )
    .unwrap();
    let golden = load_golden_float(&need(dir.join("golden_float.bin"))).unwrap();
    assert_eq!(
        (golden.input.h, golden.input.w, golden.input.c),
        in_shape,
        "golden float shape must match the tile artifact"
    );
    let out = exe.run(&golden.input).unwrap();
    let max_diff = out
        .data
        .iter()
        .zip(&golden.output.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff < 1e-4,
        "PJRT output diverged from JAX: max diff {max_diff}"
    );
}

#[test]
fn pjrt_band_artifact_contains_pallas_lowering() {
    // the band artifact is lowered through the Pallas kernel path; it
    // must compile and run on the CPU client (interpret-mode lowering)
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir).unwrap();
    let (in_shape, out_shape) =
        manifest.shapes("apbn_band.hlo.txt").unwrap();
    assert_eq!(in_shape, (60, 640, 3));
    let exe = Executor::load(
        &need(dir.join("apbn_band.hlo.txt")),
        in_shape,
        out_shape,
    )
    .unwrap();
    // feed a mid-gray band; output must be plausible (range respected)
    let band = ImageF32::from_vec(
        60,
        640,
        3,
        vec![0.5; 60 * 640 * 3],
    );
    let out = exe.run(&band).unwrap();
    assert_eq!((out.h, out.w, out.c), (180, 1920, 3));
    assert!(out.data.iter().all(|v| (0.0..=1.0).contains(v)));
}

#[test]
fn quantized_engine_close_to_float_model() {
    // end-to-end dequantization quality: int8 output vs float golden
    let dir = artifacts_dir();
    let qm = load_apbnw(&need(dir.join("weights.apbnw"))).unwrap();
    let gf = load_golden_float(&need(dir.join("golden_float.bin"))).unwrap();
    let lr_u8 = gf.input.to_u8();
    let got = reference::upscale(&lr_u8, &qm);
    let got_f = got.to_f32();
    let p = psnr(
        &got_f,
        &ImageF32::from_vec(
            gf.output.h,
            gf.output.w,
            gf.output.c,
            gf.output.data.clone(),
        ),
    );
    assert!(p > 40.0, "int8 vs float model PSNR too low: {p:.1} dB");
}
