//! End-to-end coordinator tests: the serving pipeline over real engines
//! (artifact-dependent cases skip gracefully on bare checkouts).

use sr_accel::config::{
    AcceleratorConfig, HaloPolicy, RestartPolicy, ShardPlan,
};
use sr_accel::coordinator::{
    run_pipeline, Engine, EngineFactory, FaultPlan, Int8Engine,
    PipelineConfig, SimEngine,
};
use sr_accel::image::psnr_u8;
use sr_accel::model::QuantModel;
use sr_accel::runtime::{artifacts_available, artifacts_dir};

fn int8_factories(n: usize, seed: u64) -> Vec<EngineFactory> {
    (0..n)
        .map(|_| {
            Box::new(move || {
                Ok(Box::new(Int8Engine::new(QuantModel::test_model(
                    3, 3, 6, 3, seed,
                ))) as Box<dyn Engine>)
            }) as EngineFactory
        })
        .collect()
}

fn tiny(frames: usize, workers: usize) -> PipelineConfig {
    PipelineConfig {
        frames,
        queue_depth: 3,
        workers,
        lr_w: 30,
        lr_h: 24,
        seed: 5,
        source_fps: None,
        scale: 3,
        shard: ShardPlan::whole_frame(),
        model_layers: 3,
        restart: RestartPolicy::none(),
        stall_budget_ms: None,
        inject: FaultPlan::default(),
    }
}

#[test]
fn pipeline_output_independent_of_worker_count() {
    let mut one = Vec::new();
    run_pipeline(&tiny(9, 1), int8_factories(1, 2), |_, hr| {
        one.push(hr.clone())
    })
    .unwrap();
    let mut two = Vec::new();
    run_pipeline(&tiny(9, 2), int8_factories(2, 2), |_, hr| {
        two.push(hr.clone())
    })
    .unwrap();
    assert_eq!(one.len(), 9);
    assert_eq!(one, two, "worker count must not change results");
}

#[test]
fn band_sharded_pipeline_output_matches_whole_frame() {
    let mut whole = Vec::new();
    run_pipeline(&tiny(5, 1), int8_factories(1, 8), |_, hr| {
        whole.push(hr.clone())
    })
    .unwrap();
    let cfg = PipelineConfig {
        shard: ShardPlan::row_bands(7, HaloPolicy::Exact),
        ..tiny(5, 2)
    };
    let mut banded = Vec::new();
    run_pipeline(&cfg, int8_factories(2, 8), |_, hr| {
        banded.push(hr.clone())
    })
    .unwrap();
    assert_eq!(whole, banded, "band sharding must not change results");
}

#[test]
fn backpressure_bounds_queue_wait() {
    // with pacing slower than the engine, queue wait stays ~zero
    let cfg = PipelineConfig {
        source_fps: Some(500.0),
        ..tiny(8, 1)
    };
    let rep = run_pipeline(&cfg, int8_factories(1, 3), |_, _| {}).unwrap();
    assert_eq!(rep.frames, 8);
    // paced source: median queue wait should be well under the latency
    assert!(
        rep.queue_wait_ms.median() <= rep.latency_ms.median(),
        "queue wait exceeds total latency?"
    );
}

#[test]
fn sim_engine_through_pipeline_reports_stats() {
    let qm = QuantModel::test_model(3, 3, 6, 3, 4);
    let acc = AcceleratorConfig {
        tile_rows: 12,
        tile_cols: 4,
        ..AcceleratorConfig::paper()
    };
    let mut eng = SimEngine::new(qm, acc);
    let img = sr_accel::image::SceneGenerator::new(20, 12, 3).frame(0);
    let hr = eng.upscale(&img).unwrap();
    assert_eq!((hr.h, hr.w), (36, 60));
    let stats = eng.last_stats().expect("sim engine must report stats");
    assert!(stats.compute_cycles > 0);
    assert!(stats.utilization() > 0.0 && stats.utilization() <= 1.0);
}

#[test]
fn sim_and_int8_engines_agree_when_single_band() {
    let qm = QuantModel::test_model(4, 3, 8, 3, 6);
    let acc = AcceleratorConfig {
        tile_rows: 16,
        tile_cols: 8,
        ..AcceleratorConfig::paper()
    };
    let img = sr_accel::image::SceneGenerator::new(40, 16, 9).frame(2);
    let mut sim = SimEngine::new(qm.clone(), acc);
    let mut int8 = Int8Engine::new(qm);
    let a = sim.upscale(&img).unwrap();
    let b = int8.upscale(&img).unwrap();
    assert_eq!(a, b);
}

#[test]
fn banded_vs_monolithic_psnr_penalty_small_on_natural_frames() {
    // E5's Rust-side counterpart: band seams barely hurt on smooth
    // synthetic video frames.  Uses the *trained* weights — a randomly
    // initialized trunk has no smoothness prior and falls apart at
    // seams, which is exactly why the paper trains before measuring.
    if !artifacts_available() {
        eprintln!(
            "SKIP: artifacts missing at {} — run `make artifacts`",
            artifacts_dir().display()
        );
        return;
    }
    let qm = sr_accel::model::load_apbnw(
        &artifacts_dir().join("weights.apbnw"),
    )
    .expect("weights.apbnw unreadable");
    let acc = AcceleratorConfig::paper(); // 60-row bands
    let img = sr_accel::image::SceneGenerator::new(160, 120, 11).frame(0);
    let mut sim = SimEngine::new(qm.clone(), acc);
    let banded = sim.upscale(&img).unwrap();
    let mut int8 = Int8Engine::new(qm);
    let mono = int8.upscale(&img).unwrap();
    let p = psnr_u8(&banded, &mono);
    assert!(
        p > 35.0,
        "band seams cost too much on smooth content: {p:.1} dB"
    );
}
