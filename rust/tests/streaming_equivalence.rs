//! §Streaming equivalence properties: the row-ring streaming executor
//! must be **bit-identical** to the tilted tile scheduler and to
//! monolithic band inference (`reference::forward_int`) — across
//! randomized geometries, model depths, upscale factors, band heights,
//! tile widths and kernel dispatches (`force_scalar` on/off).  A
//! whole input run as one band has no seams, so the streaming path is
//! additionally pinned bit-identical to monolithic whole-frame
//! inference — the contract `Int8Engine`'s default executor relies on.

use sr_accel::config::{AcceleratorConfig, ExecutorKind};
use sr_accel::coordinator::{Engine, Int8Engine, SimEngine};
use sr_accel::fusion::{
    band_of, band_ranges, StreamingScheduler, TiltedScheduler,
};
use sr_accel::image::ImageU8;
use sr_accel::model::{PreparedModel, QuantModel, Scratch, Tensor};
use sr_accel::reference;
use sr_accel::util::quickcheck::{check_no_shrink, Config};
use sr_accel::util::Xoshiro256pp;

fn rand_frame(h: usize, w: usize, c: usize, seed: u64) -> Tensor<u8> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut t = Tensor::new(h, w, c);
    rng.fill_u8(&mut t.data);
    // sprinkle zeros so the kernels' sparsity-skip branches run
    for i in (0..t.data.len()).step_by(11) {
        t.data[i] = 0;
    }
    t
}

fn cfg_with(tile_rows: usize, tile_cols: usize) -> AcceleratorConfig {
    AcceleratorConfig {
        tile_rows,
        tile_cols,
        ..AcceleratorConfig::paper()
    }
}

type Case = (usize, usize, usize, usize, usize, usize, usize, u64);

/// (frame_h, frame_w, layers, c_mid, scale, band_rows, tile_cols, seed)
fn case_gen(rng: &mut Xoshiro256pp) -> Case {
    (
        rng.range_usize(1, 14),  // frame_h (bands may be ragged)
        rng.range_usize(1, 18),  // frame_w (tiles may be ragged)
        rng.range_usize(1, 4),   // conv layers
        rng.range_usize(1, 9),   // trunk channels (odd, %8 != 0)
        rng.range_usize(1, 4),   // upscale factor
        rng.range_usize(1, 8),   // band height
        rng.range_usize(2, 6),   // tile columns (tilted needs >= 2)
        rng.next_u64(),
    )
}

#[test]
fn prop_streaming_matches_tilted_and_reference() {
    let cfg = Config {
        cases: 48,
        seed: 0x57AE,
        max_shrink_iters: 0,
    };
    // one scratch per executor across all cases: ring/pool reuse must
    // never leak state between geometries
    let mut s_scratch = Scratch::new();
    let mut t_scratch = Scratch::new();
    check_no_shrink(
        &cfg,
        case_gen,
        |&(fh, fw, layers, c_mid, scale, band_rows, tile_cols, seed)| {
            let qm = QuantModel::test_model(layers, 3, c_mid, scale, seed);
            let pm = PreparedModel::new(&qm);
            let acc = cfg_with(band_rows, tile_cols);
            let frame = rand_frame(fh, fw, 3, seed ^ 0xA5);
            let force_scalar = seed & 1 == 0;
            let streaming = StreamingScheduler { force_scalar };
            let tilted = TiltedScheduler::default();

            // band-level: streaming == monolithic band == tilted band
            for (y0, y1) in band_ranges(fh, band_rows) {
                let band = band_of(&frame, y0, y1);
                let want = reference::forward_int(&band, &qm);
                let (got, _) =
                    streaming.run_band_prepared(&band, &pm, &mut s_scratch);
                if got.data != want.data {
                    return Err(format!(
                        "streaming band [{y0},{y1}) != reference \
                         ({fh}x{fw}, {layers}l c{c_mid} x{scale}, \
                         force_scalar={force_scalar})"
                    ));
                }
                let (tband, _) = tilted.run_band_prepared(
                    &band,
                    &pm,
                    &acc,
                    &mut t_scratch,
                );
                if got.data != tband.data {
                    return Err(format!(
                        "streaming band [{y0},{y1}) != tilted \
                         ({fh}x{fw}, {layers}l c{c_mid} x{scale}, \
                         tile_cols={tile_cols})"
                    ));
                }
                s_scratch.recycle_u8(got);
                s_scratch.recycle_u8(tband);
            }

            // frame-level: identical band split, identical HR frame
            let sf = streaming.run_frame_prepared(
                &frame,
                &pm,
                &acc,
                &mut s_scratch,
            );
            let tf = tilted.run_frame_prepared(
                &frame,
                &pm,
                &acc,
                &mut t_scratch,
            );
            if sf.hr.data != tf.hr.data {
                return Err(format!(
                    "streaming frame != tilted frame ({fh}x{fw}, \
                     band_rows={band_rows}, tile_cols={tile_cols})"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_whole_input_single_band_is_monolithic() {
    // no seams: the streaming executor over the whole input must be
    // bit-identical to reference::forward_int — the Int8Engine fast
    // path's contract
    let cfg = Config {
        cases: 32,
        seed: 0x60D5,
        max_shrink_iters: 0,
    };
    let mut scratch = Scratch::new();
    check_no_shrink(
        &cfg,
        case_gen,
        |&(fh, fw, layers, c_mid, scale, _band_rows, _tile_cols, seed)| {
            let qm = QuantModel::test_model(layers, 3, c_mid, scale, seed);
            let pm = PreparedModel::new(&qm);
            let frame = rand_frame(fh, fw, 3, seed ^ 0x3C);
            let force_scalar = seed & 1 == 0;
            let got = StreamingScheduler { force_scalar }
                .run_whole_prepared(&frame, &pm, &mut scratch);
            let want = reference::forward_int(&frame, &qm);
            if got.data != want.data {
                return Err(format!(
                    "whole-input streaming != monolithic ({fh}x{fw}, \
                     {layers}l c{c_mid} x{scale}, \
                     force_scalar={force_scalar})"
                ));
            }
            scratch.recycle_u8(got);
            Ok(())
        },
    );
}

#[test]
fn engines_agree_across_executors() {
    // the coordinator wiring: Int8Engine streaming == Int8Engine
    // legacy == reference::upscale; SimEngine streaming == SimEngine
    // tilted (band-seamed) — across several frames through one engine
    // so scratch reuse is covered
    let qm = QuantModel::test_model(3, 3, 6, 3, 17);
    let acc = cfg_with(5, 4);
    let mut int8_fast =
        Int8Engine::with_executor(qm.clone(), ExecutorKind::Streaming);
    let mut int8_legacy =
        Int8Engine::with_executor(qm.clone(), ExecutorKind::Tilted);
    let mut sim_fast = SimEngine::with_executor(
        qm.clone(),
        acc.clone(),
        ExecutorKind::Streaming,
    );
    let mut sim_tilted =
        SimEngine::with_executor(qm.clone(), acc, ExecutorKind::Tilted);
    for seed in 0..4u64 {
        let mut rng = Xoshiro256pp::seed_from_u64(40 + seed);
        let mut lr = ImageU8::new(11, 13, 3);
        rng.fill_u8(&mut lr.data);
        let fast = int8_fast.upscale(&lr).unwrap();
        let legacy = int8_legacy.upscale(&lr).unwrap();
        assert_eq!(fast, legacy, "int8 executors diverged, frame {seed}");
        let want = reference::upscale(&lr, &qm);
        assert_eq!(fast, want, "int8 streaming != reference, frame {seed}");
        assert_eq!(
            sim_fast.upscale(&lr).unwrap(),
            sim_tilted.upscale(&lr).unwrap(),
            "sim executors diverged, frame {seed}"
        );
    }
}

#[test]
fn prop_scratch_survives_geometry_churn() {
    // one Scratch reused across shrink-then-grow geometry churn (band
    // width and trunk channel count both jump big -> small -> big ->
    // small through the streaming executor) must be bit-identical to
    // a fresh Scratch built for every band: recycled ring rows and
    // pooled tensors carry stale sizes and stale bytes between
    // geometries, and none of that may leak into the output
    let cfg = Config {
        cases: 24,
        seed: 0x5C2A,
        max_shrink_iters: 0,
    };
    let mut churned = Scratch::new();
    check_no_shrink(
        &cfg,
        |rng| {
            (
                rng.range_usize(10, 18), // big frame_w
                rng.range_usize(1, 5),   // small frame_w
                rng.range_usize(8, 12),  // big c_mid
                rng.range_usize(1, 4),   // small c_mid
                rng.range_usize(1, 4),   // layers
                rng.range_usize(1, 4),   // scale
                rng.next_u64(),
            )
        },
        |&(w_big, w_small, c_big, c_small, layers, scale, seed)| {
            // big -> small -> big -> small, on both axes at once, then
            // crossed so each axis also shrinks while the other grows
            let churn = [
                (w_big, c_big),
                (w_small, c_small),
                (w_big, c_big),
                (w_small, c_small),
                (w_big, c_small),
                (w_small, c_big),
            ];
            let streaming = StreamingScheduler { force_scalar: false };
            for (step, &(fw, c_mid)) in churn.iter().enumerate() {
                let qm = QuantModel::test_model(
                    layers,
                    3,
                    c_mid,
                    scale,
                    seed ^ step as u64,
                );
                let pm = PreparedModel::new(&qm);
                let band = rand_frame(4, fw, 3, seed ^ ((step as u64) << 8));
                let (got, _) =
                    streaming.run_band_prepared(&band, &pm, &mut churned);
                let mut fresh = Scratch::new();
                let (want, _) =
                    streaming.run_band_prepared(&band, &pm, &mut fresh);
                if got.data != want.data {
                    return Err(format!(
                        "churned scratch diverged at step {step} \
                         (4x{fw} c{c_mid}, {layers}l x{scale})"
                    ));
                }
                churned.recycle_u8(got);
            }
            Ok(())
        },
    );
}

#[test]
fn streaming_handles_bands_shorter_than_the_ring() {
    // 1- and 2-row bands: the 3-row ring is never filled, every conv
    // row sees at least one zero seam row
    let qm = QuantModel::test_model(3, 3, 5, 2, 9);
    let pm = PreparedModel::new(&qm);
    let mut scratch = Scratch::new();
    let frame = rand_frame(5, 7, 3, 2);
    for band_rows in [1usize, 2] {
        let acc = cfg_with(band_rows, 3);
        let sf = StreamingScheduler::default().run_frame_prepared(
            &frame,
            &pm,
            &acc,
            &mut scratch,
        );
        for (i, (y0, y1)) in
            band_ranges(frame.h, band_rows).into_iter().enumerate()
        {
            let band = band_of(&frame, y0, y1);
            let want = reference::forward_int(&band, &qm);
            let got = &sf.hr.data[y0 * 2 * sf.hr.w * 3..y1 * 2 * sf.hr.w * 3];
            assert_eq!(got, &want.data[..], "band {i} rows={band_rows}");
        }
    }
}
