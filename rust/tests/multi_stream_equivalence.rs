//! Property tests of the multi-stream serving front-end
//! (`coordinator::server`): multiplexing N streams over one shared
//! worker pool must never change any stream's pixels.
//!
//! The tentpole property: under `RtPolicy::BestEffort`, each stream's
//! delivered frames are **bit-identical and in display order** vs
//! running that stream alone through `run_pipeline`, across randomized
//! stream counts, geometries, upscale factors and worker counts.
//! Under `RtPolicy::DropLate`, an undersized pool sheds frames — but
//! every offered frame is accounted for (delivered + dropped +
//! incomplete) and delivery order still holds per stream.

use sr_accel::config::{RestartPolicy, RtPolicy, ShardPlan, StreamSpec};
use sr_accel::coordinator::{
    run_pipeline, serve_multi, stream_seed, Engine, EngineFactory,
    FaultPlan, Int8Engine, MultiServeConfig, PipelineConfig,
    ScaleEngineFactory,
};
use sr_accel::image::ImageU8;
use sr_accel::model::QuantModel;
use sr_accel::util::quickcheck::{check, shrink_dims, Config};

fn test_model(
    layers: usize,
    c_mid: usize,
    scale: usize,
    model_seed: u64,
) -> QuantModel {
    QuantModel::test_model(layers, 3, c_mid, scale, model_seed)
}

/// Run one stream alone through the single-stream pipeline, with the
/// same source seed and engine weights `serve_multi` would use.
fn solo_frames(
    spec: &StreamSpec,
    frames: usize,
    source_seed: u64,
    layers: usize,
    c_mid: usize,
    model_seed: u64,
) -> Vec<ImageU8> {
    let cfg = PipelineConfig {
        frames,
        queue_depth: 2,
        workers: 1,
        lr_w: spec.lr_w,
        lr_h: spec.lr_h,
        seed: source_seed,
        source_fps: None,
        scale: spec.scale,
        shard: ShardPlan::whole_frame(),
        model_layers: layers,
        restart: RestartPolicy::none(),
        stall_budget_ms: None,
        inject: FaultPlan::default(),
    };
    let scale = spec.scale;
    let factories: Vec<EngineFactory> = vec![Box::new(move || {
        Ok(Box::new(Int8Engine::new(test_model(
            layers, c_mid, scale, model_seed,
        ))) as Box<dyn Engine>)
    })];
    let mut out = Vec::new();
    run_pipeline(&cfg, factories, |_, hr| out.push(hr.clone()))
        .expect("solo pipeline failed");
    out
}

fn multi_factories(
    workers: usize,
    layers: usize,
    c_mid: usize,
    model_seed: u64,
) -> Vec<ScaleEngineFactory> {
    (0..workers)
        .map(|_| {
            Box::new(move |scale: usize| {
                Ok(Box::new(Int8Engine::new(test_model(
                    layers, c_mid, scale, model_seed,
                ))) as Box<dyn Engine>)
            }) as ScaleEngineFactory
        })
        .collect()
}

/// Mixed-geometry/scale table the randomized streams draw from.
const GEOMS: [(usize, usize, usize); 3] =
    [(14, 10, 3), (12, 8, 2), (10, 12, 4)];

fn streams_for(n: usize) -> Vec<StreamSpec> {
    (0..n)
        .map(|i| {
            let (w, h, s) = GEOMS[i % GEOMS.len()];
            StreamSpec {
                label: format!("s{i}:{w}x{h}@x{s}"),
                lr_w: w,
                lr_h: h,
                scale: s,
                fps: None,
            }
        })
        .collect()
}

/// The tentpole property (ISSUE 3 acceptance): best-effort multi-
/// stream serving is bit-identical, per stream and in order, to solo
/// runs.
#[test]
fn prop_best_effort_multi_stream_matches_solo_runs() {
    let cfg = Config {
        cases: 8,
        seed: 0x3575_0CA7,
        max_shrink_iters: 24,
    };
    check(
        &cfg,
        |rng| {
            vec![
                rng.range_usize(1, 3),   // streams
                rng.range_usize(1, 3),   // workers
                rng.range_usize(1, 2),   // model layers
                rng.range_usize(1, 4),   // mid channels
                rng.range_usize(0, 99),  // model seed
                rng.range_usize(0, 999), // base source seed
            ]
        },
        |d| {
            let (n, workers, layers, c_mid) =
                (d[0].max(1), d[1].max(1), d[2].max(1), d[3].max(1));
            let model_seed = d[4] as u64;
            let base_seed = d[5] as u64;
            let frames = 3;
            let streams = streams_for(n);
            let mcfg = MultiServeConfig {
                streams: streams.clone(),
                frames,
                workers,
                queue_depth: 2,
                policy: RtPolicy::BestEffort,
                seed: base_seed,
                restart: RestartPolicy::none(),
                inject: FaultPlan::default(),
                stall_budget_ms: None,
            };
            let mut got: Vec<Vec<(usize, ImageU8)>> = vec![Vec::new(); n];
            let rep = serve_multi(
                &mcfg,
                multi_factories(workers, layers, c_mid, model_seed),
                |si, fi, hr| got[si].push((fi, hr.clone())),
            )
            .map_err(|e| format!("serve_multi failed: {e:#}"))?;
            if rep.dropped != 0 || rep.incomplete != 0 {
                return Err(format!(
                    "best-effort lost frames: dropped={} incomplete={}",
                    rep.dropped, rep.incomplete
                ));
            }
            for (si, spec) in streams.iter().enumerate() {
                let idx: Vec<usize> =
                    got[si].iter().map(|(i, _)| *i).collect();
                if idx != (0..frames).collect::<Vec<_>>() {
                    return Err(format!(
                        "stream {si} delivered out of order: {idx:?}"
                    ));
                }
                let want = solo_frames(
                    spec,
                    frames,
                    stream_seed(base_seed, si),
                    layers,
                    c_mid,
                    model_seed,
                );
                for (f, (_, hr)) in got[si].iter().enumerate() {
                    if *hr != want[f] {
                        return Err(format!(
                            "stream {si} ({}) frame {f} differs from \
                             solo run (n={n}, workers={workers})",
                            spec.label
                        ));
                    }
                }
            }
            Ok(())
        },
        |d| shrink_dims(d, &[1, 1, 1, 1, 0, 0]),
    );
}

/// Acceptance pin: >= 3 concurrent streams with >= 2 distinct
/// (geometry, scale) pairs over a shared pool, explicitly compared
/// stream-by-stream against solo runs.
#[test]
fn three_heterogeneous_streams_bit_identical_to_solo() {
    let (layers, c_mid, model_seed, base_seed) = (2, 4, 21, 11u64);
    let frames = 4;
    let streams = streams_for(3);
    // the acceptance criterion: distinct (geometry, scale) pairs
    assert!(
        streams
            .iter()
            .map(|s| (s.lr_w, s.lr_h, s.scale))
            .collect::<std::collections::BTreeSet<_>>()
            .len()
            >= 2
    );
    for workers in [1, 2, 3] {
        let mcfg = MultiServeConfig {
            streams: streams.clone(),
            frames,
            workers,
            queue_depth: 3,
            policy: RtPolicy::BestEffort,
            seed: base_seed,
            restart: RestartPolicy::none(),
            inject: FaultPlan::default(),
            stall_budget_ms: None,
        };
        let mut got: Vec<Vec<ImageU8>> = vec![Vec::new(); 3];
        let rep = serve_multi(
            &mcfg,
            multi_factories(workers, layers, c_mid, model_seed),
            |si, _, hr| got[si].push(hr.clone()),
        )
        .unwrap();
        assert_eq!(rep.frames, 3 * frames);
        assert_eq!(rep.dropped, 0);
        assert_eq!(rep.workers, workers);
        for (si, spec) in streams.iter().enumerate() {
            let want = solo_frames(
                spec,
                frames,
                stream_seed(base_seed, si),
                layers,
                c_mid,
                model_seed,
            );
            assert_eq!(
                got[si], want,
                "stream {si} differs (workers={workers})"
            );
        }
    }
}

/// DropLate under an undersized pool records a nonzero drop rate while
/// still accounting for every offered frame and preserving per-stream
/// delivery order (the other half of the ISSUE 3 acceptance).
#[test]
fn drop_late_records_nonzero_drop_rate_under_undersized_pool() {
    let streams = streams_for(3);
    let mcfg = MultiServeConfig {
        streams: streams.clone(),
        frames: 25,
        workers: 1,   // undersized:
        queue_depth: 1, // 3 fast sources vs 1 worker, 1 queue slot
        policy: RtPolicy::DropLate { deadline_ms: 0.0 },
        seed: 19,
        restart: RestartPolicy::none(),
        inject: FaultPlan::default(),
        stall_budget_ms: None,
    };
    let mut got: Vec<Vec<usize>> = vec![Vec::new(); 3];
    let rep = serve_multi(
        &mcfg,
        multi_factories(1, 1, 2, 5),
        |si, fi, _| got[si].push(fi),
    )
    .unwrap();
    assert!(rep.dropped > 0, "undersized pool must shed frames");
    assert!(rep.drop_rate > 0.0);
    for (si, s) in rep.streams.iter().enumerate() {
        assert_eq!(s.meta.offered, 25);
        assert_eq!(
            s.meta.offered,
            s.delivered + s.meta.dropped + s.incomplete,
            "stream {si}: every offered frame accounted for"
        );
        assert!(
            got[si].windows(2).all(|w| w[0] < w[1]),
            "stream {si} delivered out of order: {:?}",
            got[si]
        );
    }
    // the report renders the delivery breakdown
    assert!(rep.render().contains("delivery:"));
    assert!(rep.render().contains("drop"));
}

/// §Supervision x shed history (PR 9 satellite): when a worker dies
/// mid-frame and hands its in-flight frame to a survivor under
/// `DropLate`, every admitted frame must terminate **exactly once** —
/// delivered once, in order, or shed once — never delivered twice, and
/// never counted as both dropped and incomplete.
#[test]
fn rescued_frames_terminate_exactly_once_under_drop_late() {
    let streams = streams_for(2);
    let mcfg = MultiServeConfig {
        streams: streams.clone(),
        frames: 12,
        workers: 2,
        queue_depth: 1, // fast sources vs 1 slot: admission sheds too
        policy: RtPolicy::DropLate { deadline_ms: 1e6 },
        seed: 23,
        restart: RestartPolicy::none(),
        inject: FaultPlan::default(),
        stall_budget_ms: None,
    };
    // worker 0 can never build an engine: with a zero restart budget it
    // exhausts on the first frame it picks up and must hand that frame
    // to worker 1 over the retry channel instead of losing it
    let mut factories = multi_factories(2, 1, 2, 5);
    factories[0] =
        Box::new(|_| anyhow::bail!("poisoned worker (factory)"));
    let mut got: Vec<Vec<usize>> = vec![Vec::new(); 2];
    let rep = serve_multi(&mcfg, factories, |si, fi, _| {
        got[si].push(fi)
    })
    .unwrap();
    // worker 1 survives and drains the retry channel before retiring,
    // so a rescued frame is delivered or shed — never silently lost
    assert_eq!(rep.incomplete, 0, "survivor must rescue in-flight work");
    assert!(
        rep.errors.len() <= 1,
        "only worker 0 may die: {:?}",
        rep.errors
    );
    if let Some(e) = rep.errors.first() {
        assert!(e.contains("restart budget of 0"), "{e}");
    }
    let mut delivered_total = 0;
    for (si, s) in rep.streams.iter().enumerate() {
        assert_eq!(s.meta.offered, 12);
        // the satellite property: terminal states partition offered
        // frames — nothing double-counted dropped *and* incomplete
        assert_eq!(
            s.meta.offered,
            s.delivered + s.meta.dropped + s.incomplete,
            "stream {si} accounting"
        );
        // strictly increasing indices == no frame delivered twice and
        // display order preserved across the rescue
        assert!(
            got[si].windows(2).all(|w| w[0] < w[1]),
            "stream {si} duplicated or reordered: {:?}",
            got[si]
        );
        assert_eq!(got[si].len(), s.delivered);
        delivered_total += s.delivered;
    }
    assert_eq!(rep.frames, delivered_total);
}
