//! Chaos suite for the self-healing serving pipeline (§Supervision +
//! §Watchdog): seeded fault plans drive the real restart/backoff,
//! retry-rescue, hung-worker-reaping and quality-ladder code paths,
//! across the full fault x policy x worker matrix.  CI additionally
//! runs this file under ThreadSanitizer.
//!
//! Invariants exercised:
//! * no injected panic ever escapes `serve_multi` — faults surface as
//!   restarts, report errors, or a clean `Err`, never an abort;
//! * every offered frame is accounted: delivered + dropped +
//!   incomplete per stream, with degraded a subset of delivered;
//! * with restart budget, delivered frames are bit-identical to the
//!   fault-free run (supervision never trades pixels for liveness) —
//!   including when the fault is a true hang that only the armed
//!   watchdog can unwind;
//! * a zombified worker's late result is discarded by the generation
//!   check — a frame terminates exactly once, never delivered twice;
//! * injected faults are visible in the report (`restarts`, `dropped`,
//!   `degraded`, `hangs_detected`, `errors`) where the schedule makes
//!   them deterministic;
//! * under overload, `Degrade` beats `DropLate` on goodput with zero
//!   undelivered frames (the ISSUE 9 acceptance pair), and its
//!   `Reduced` rung is bit-exact against an offline x2-SR + bilinear
//!   reference (the ISSUE 10 ladder).
//!
//! Geometries are deliberately tiny: TSan runs this whole file.  Stall
//! budgets are armed only on rows that inject a hang — a 75 ms budget
//! keeps TSan's 10-20x slowdown clear of false zombies.

use std::time::Instant;

use sr_accel::config::{RestartPolicy, RtPolicy, StreamSpec};
use sr_accel::coordinator::{
    serve_multi, stream_seed, Engine, FaultPlan, Int8Engine,
    MultiServeConfig, ScaleEngineFactory,
};
use sr_accel::image::{bilinear_upsample, ImageU8, SceneGenerator};
use sr_accel::model::QuantModel;

fn spec(label: &str, w: usize, h: usize, scale: usize) -> StreamSpec {
    StreamSpec {
        label: label.to_string(),
        lr_w: w,
        lr_h: h,
        scale,
        fps: None,
    }
}

fn int8_factories(workers: usize, seed: u64) -> Vec<ScaleEngineFactory> {
    (0..workers)
        .map(|_| {
            Box::new(move |scale: usize| {
                Ok(Box::new(Int8Engine::new(QuantModel::test_model(
                    2, 3, 4, scale, seed,
                ))) as Box<dyn Engine>)
            }) as ScaleEngineFactory
        })
        .collect()
}

/// Fast supervision for tests: generous budget, ~1 ms backoff.
fn quick_restart(max: usize) -> RestartPolicy {
    RestartPolicy {
        max_restarts: max,
        backoff_base_ms: 1.0,
        backoff_cap_ms: 4.0,
    }
}

type Delivered = Vec<Vec<(usize, ImageU8)>>;

fn run(
    cfg: &MultiServeConfig,
    seed: u64,
) -> (Delivered, sr_accel::coordinator::PipelineReport) {
    let n = cfg.streams.len();
    let mut got: Delivered = vec![Vec::new(); n];
    let rep = serve_multi(
        cfg,
        int8_factories(cfg.workers, seed),
        |si, fi, hr| got[si].push((fi, hr.clone())),
    )
    .expect("serve_multi must not fail while any worker survives");
    (got, rep)
}

fn assert_accounting(rep: &sr_accel::coordinator::PipelineReport) {
    let mut degraded_total = 0;
    for (si, s) in rep.streams.iter().enumerate() {
        assert_eq!(
            s.meta.offered,
            s.delivered + s.meta.dropped + s.incomplete,
            "stream {si}: offered must partition into terminal states"
        );
        assert!(
            s.degraded <= s.delivered,
            "stream {si}: degraded ({}) must be a subset of delivered \
             ({})",
            s.degraded,
            s.delivered
        );
        degraded_total += s.degraded;
    }
    assert_eq!(rep.degraded, degraded_total);
}

/// The full matrix: (panic | error | stall-past-deadline | hang |
/// persistent slowdown) x (BestEffort | DropLate | Degrade) x
/// (1 | 2 | 4 workers).  No panic escapes, accounting always holds,
/// and with budget no error surfaces.  Where the schedule is
/// deterministic (1 worker), the fault must be visible in the report.
#[test]
fn fault_matrix_never_escapes_and_always_accounts() {
    // every fault fires on the worker's *first* engine call: frame 0
    // is dequeued microseconds after emission, so the call happens (and
    // the fault fires) under every policy regardless of scheduler
    // timing — later indices could starve if frames go late under a
    // sanitizer's slowdown.  Only the hang rows arm the watchdog (a
    // hang is unrecoverable without it); healthy rows stay disarmed so
    // sanitizer slowdowns can never fake a zombie.
    let faults: [(&str, Option<f64>); 5] = [
        ("w0:panic@0", None),
        ("w0:error@0", None),
        ("w0:stall:25@0", None),
        ("w0:hang@0", Some(75.0)),
        ("w0:slow:3@0", None),
    ];
    let policies = [
        RtPolicy::BestEffort,
        RtPolicy::DropLate { deadline_ms: 5.0 },
        RtPolicy::Degrade { deadline_ms: 5.0 },
    ];
    for (fault, stall_budget_ms) in faults {
        for policy in policies {
            for workers in [1usize, 2, 4] {
                let cfg = MultiServeConfig {
                    streams: vec![spec("a", 10, 8, 2)],
                    frames: 6,
                    workers,
                    queue_depth: 2,
                    policy,
                    seed: 3,
                    restart: quick_restart(3),
                    inject: FaultPlan::parse(fault).unwrap(),
                    stall_budget_ms,
                };
                let (got, rep) = run(&cfg, 9);
                let tag = format!(
                    "fault={fault} policy={} workers={workers}",
                    policy.name()
                );
                assert_accounting(&rep);
                assert!(
                    rep.errors.is_empty(),
                    "{tag}: budget 3 must absorb one fault: {:?}",
                    rep.errors
                );
                // delivery order survives the chaos
                let idx: Vec<usize> =
                    got[0].iter().map(|(i, _)| *i).collect();
                assert!(
                    idx.windows(2).all(|w| w[0] < w[1]),
                    "{tag}: out of order: {idx:?}"
                );
                // one worker serializes the schedule: its first engine
                // call deterministically hits the fault
                if workers == 1
                    && (fault.contains("panic") || fault.contains("error"))
                {
                    assert_eq!(rep.restarts, 1, "{tag}");
                }
                if workers == 1 && fault.contains("hang") {
                    // the sole worker's first call parks forever: the
                    // watchdog must reap it exactly once and replace it
                    assert_eq!(rep.hangs_detected, 1, "{tag}");
                    assert_eq!(rep.restarts, 1, "{tag}: hangs charge \
                         the same restart budget");
                }
                if fault.contains("stall") || fault.contains("slow") {
                    // slowness is not failure: never a restart, and
                    // with the watchdog disarmed, never a zombie
                    assert_eq!(rep.restarts, 0, "{tag}");
                    assert_eq!(rep.hangs_detected, 0, "{tag}");
                }
                if matches!(policy, RtPolicy::BestEffort) {
                    // best-effort + budget: every frame full quality
                    assert_eq!(rep.frames, 6, "{tag}");
                    assert_eq!(rep.dropped, 0, "{tag}");
                    assert_eq!(rep.degraded, 0, "{tag}");
                }
                if matches!(policy, RtPolicy::Degrade { .. }) {
                    // degrade admits like best-effort: zero undelivered
                    assert_eq!(rep.dropped, 0, "{tag}");
                    assert_eq!(rep.incomplete, 0, "{tag}");
                    assert_eq!(rep.frames, 6, "{tag}");
                }
            }
        }
    }
}

/// Injected faults must not change a single delivered bit under
/// best-effort with restart budget — compared against the fault-free
/// run, per fault kind.
#[test]
fn best_effort_delivery_is_bit_identical_across_fault_kinds() {
    let run_with = |inject: &str, restart: RestartPolicy| {
        let cfg = MultiServeConfig {
            streams: vec![spec("a", 10, 8, 2), spec("b", 8, 6, 3)],
            frames: 4,
            workers: 1, // serialize so every fault fires deterministically
            queue_depth: 2,
            policy: RtPolicy::BestEffort,
            seed: 5,
            restart,
            inject: FaultPlan::parse(inject).unwrap(),
            stall_budget_ms: None,
        };
        run(&cfg, 13)
    };
    let (clean, clean_rep) = run_with("", RestartPolicy::none());
    assert_eq!(clean_rep.frames, 8);
    for fault in ["w0:panic@2", "w0:error@0", "w0:stall:10@1"] {
        let (got, rep) = run_with(fault, quick_restart(2));
        assert_eq!(
            got, clean,
            "{fault}: delivery must be bit-identical to the clean run"
        );
        assert_eq!(rep.incomplete, 0, "{fault}");
        assert!(rep.errors.is_empty(), "{fault}: {:?}", rep.errors);
        if !fault.contains("stall") {
            assert_eq!(rep.restarts, 1, "{fault}");
            assert!(
                rep.render().contains("supervisor: 1 worker restart"),
                "{fault}: restart missing from report"
            );
        }
    }
}

/// The ISSUE 9 acceptance shape: a seeded fault plan kills one of two
/// workers mid-run; the pool still delivers 100% of frames,
/// bit-identical to the fault-free run.
#[test]
fn killing_one_of_two_workers_loses_nothing() {
    let run_with = |inject: &str, restart: RestartPolicy| {
        let cfg = MultiServeConfig {
            streams: vec![spec("a", 10, 8, 2), spec("b", 8, 6, 3)],
            frames: 8,
            workers: 2,
            queue_depth: 2,
            policy: RtPolicy::BestEffort,
            seed: 7,
            restart,
            inject: if inject.is_empty() {
                FaultPlan::default()
            } else {
                FaultPlan::parse(inject).unwrap()
            },
            stall_budget_ms: None,
        };
        run(&cfg, 17)
    };
    let (clean, _) = run_with("", RestartPolicy::none());
    // worker 0 panics on every engine call it attempts until its
    // budget absorbs it; the shared-queue protocol guarantees worker 1
    // keeps serving throughout
    let (got, rep) = run_with("w0:panic@0,w0:panic@1", quick_restart(2));
    assert_eq!(got, clean, "fault run must be bit-identical");
    assert_eq!(rep.frames, 16, "100% of frames delivered");
    assert_eq!(rep.dropped, 0);
    assert_eq!(rep.incomplete, 0);
    assert!(rep.errors.is_empty(), "{:?}", rep.errors);
    assert_accounting(&rep);
}

/// When every worker exhausts its budget the run ends with a clean
/// error — not a hang, not a panic — and nothing was delivered to
/// mis-report.
#[test]
fn all_workers_exhausted_is_a_clean_error() {
    let cfg = MultiServeConfig {
        streams: vec![spec("a", 8, 6, 2)],
        frames: 4,
        workers: 1,
        queue_depth: 1,
        policy: RtPolicy::BestEffort,
        seed: 2,
        restart: RestartPolicy::none(), // first failure is fatal
        inject: FaultPlan::parse("w0:panic@0").unwrap(),
        stall_budget_ms: None,
    };
    let err = serve_multi(&cfg, int8_factories(1, 3), |_, _, _| {})
        .expect_err("sole worker dies on frame 0: nothing delivered");
    let msg = format!("{err:#}");
    assert!(msg.contains("no frames"), "{msg}");
    assert!(msg.contains("restart budget of 0"), "{msg}");
}

/// The ISSUE 9 acceptance pair, overload half: with a deadline no
/// frame can meet and an undersized pool, `Degrade` delivers strictly
/// more goodput than `DropLate` and leaves zero frames undelivered.
#[test]
fn overloaded_degrade_outdelivers_drop_late_with_zero_undelivered() {
    let run_policy = |policy: RtPolicy| {
        let cfg = MultiServeConfig {
            streams: vec![
                spec("a", 10, 8, 2),
                spec("b", 8, 6, 3),
                spec("c", 8, 8, 2),
            ],
            frames: 10,
            workers: 1,     // undersized on purpose:
            queue_depth: 1, // 3 fast sources vs 1 worker, 1 slot
            policy,
            seed: 29,
            restart: RestartPolicy::none(),
            inject: FaultPlan::default(),
            stall_budget_ms: None,
        };
        run(&cfg, 23).1
    };
    let drop_rep = run_policy(RtPolicy::DropLate { deadline_ms: 0.01 });
    let degr_rep = run_policy(RtPolicy::Degrade { deadline_ms: 0.01 });
    assert_accounting(&drop_rep);
    assert_accounting(&degr_rep);
    assert!(
        drop_rep.dropped > 0,
        "overload must shed under DropLate: {}",
        drop_rep.dropped
    );
    // Degrade: zero undelivered — every offered frame arrives, late
    // ones on the bilinear path
    assert_eq!(degr_rep.dropped, 0);
    assert_eq!(degr_rep.incomplete, 0);
    assert_eq!(degr_rep.frames, 30, "all offered frames delivered");
    assert!(degr_rep.degraded > 0, "overload must show in the report");
    assert!(
        degr_rep.frames > drop_rep.frames,
        "degrade goodput ({}) must strictly beat drop-late ({})",
        degr_rep.frames,
        drop_rep.frames
    );
}

/// Faults injected while `Degrade` is active: the bilinear path makes
/// no engine calls, so fault indices keep counting real engine
/// attempts and the stream still loses nothing.
#[test]
fn degrade_with_engine_faults_still_loses_nothing() {
    let cfg = MultiServeConfig {
        streams: vec![spec("a", 10, 8, 2)],
        frames: 8,
        workers: 1,
        queue_depth: 1,
        policy: RtPolicy::Degrade { deadline_ms: 0.01 },
        seed: 31,
        restart: quick_restart(2),
        inject: FaultPlan::parse("w0:panic@0").unwrap(),
        stall_budget_ms: None,
    };
    let (got, rep) = run(&cfg, 19);
    assert_eq!(rep.frames, 8, "degrade never sheds");
    assert_eq!(rep.dropped, 0);
    assert_eq!(rep.incomplete, 0);
    assert!(rep.errors.is_empty(), "{:?}", rep.errors);
    assert_accounting(&rep);
    let idx: Vec<usize> = got[0].iter().map(|(i, _)| *i).collect();
    assert_eq!(idx, (0..8).collect::<Vec<_>>());
}

/// The ISSUE 10 acceptance shape: a hang on 1 of 2 workers, under
/// *every* policy, still delivers 100% of frames bit-identical to the
/// fault-free run, with exactly one hang detected and recovery well
/// inside the run.  Deadlines are generous enough that no frame is
/// ever late, so `DropLate` and `Degrade` deliver the same pixels as
/// `BestEffort` and one clean reference covers all three policies.
#[test]
fn hang_on_one_of_two_workers_recovers_under_every_policy() {
    let run_with = |policy: RtPolicy,
                    inject: &str,
                    stall: Option<f64>,
                    restart: RestartPolicy| {
        let cfg = MultiServeConfig {
            streams: vec![spec("a", 10, 8, 2), spec("b", 8, 6, 3)],
            frames: 6,
            workers: 2,
            queue_depth: 2,
            policy,
            seed: 41,
            restart,
            inject: if inject.is_empty() {
                FaultPlan::default()
            } else {
                FaultPlan::parse(inject).unwrap()
            },
            stall_budget_ms: stall,
        };
        run(&cfg, 37)
    };
    let (clean, clean_rep) = run_with(
        RtPolicy::BestEffort,
        "",
        None,
        RestartPolicy::none(),
    );
    assert_eq!(clean_rep.frames, 12);
    let policies = [
        RtPolicy::BestEffort,
        RtPolicy::DropLate { deadline_ms: 1e6 },
        RtPolicy::Degrade { deadline_ms: 1e6 },
    ];
    for policy in policies {
        let t0 = Instant::now();
        let (got, rep) =
            run_with(policy, "w0:hang@0", Some(75.0), quick_restart(2));
        let tag = policy.name();
        assert_eq!(got, clean, "{tag}: rescue must be bit-identical");
        assert_eq!(rep.frames, 12, "{tag}: 100% of frames delivered");
        assert_eq!(rep.dropped, 0, "{tag}");
        assert_eq!(rep.incomplete, 0, "{tag}");
        assert_eq!(rep.degraded, 0, "{tag}: on-time frames stay Full");
        assert_eq!(rep.hangs_detected, 1, "{tag}: exactly one hang");
        assert!(rep.restarts >= 1, "{tag}: the reap charges a restart");
        assert!(rep.errors.is_empty(), "{tag}: {:?}", rep.errors);
        assert_accounting(&rep);
        assert!(
            rep.render().contains("watchdog: 1 hang detected"),
            "{tag}: {}",
            rep.render()
        );
        // recovery bound, deliberately loose for sanitizer runs: the
        // budget (75 ms) + monitor tick + replacement backoff is well
        // under a second; the whole 12-frame run finishing is the
        // recovery proof
        assert!(
            t0.elapsed().as_secs() < 30,
            "{tag}: run took {:?}",
            t0.elapsed()
        );
    }
}

/// §Watchdog exactly-once (the generation tag): the zombified worker
/// wakes when its token is cancelled and tries to report its stale
/// result — which must be discarded, never delivered, while the
/// rescued copy of the same frame terminates exactly once through a
/// survivor.  Mirrors `rescued_frames_terminate_exactly_once_under_
/// drop_late` with a hang instead of a dead factory.
#[test]
fn zombie_late_result_is_discarded_never_delivered_twice() {
    let cfg = MultiServeConfig {
        streams: vec![spec("a", 10, 8, 2), spec("b", 8, 6, 3)],
        frames: 12,
        workers: 2,
        queue_depth: 1, // fast sources vs 1 slot: admission sheds too
        policy: RtPolicy::DropLate { deadline_ms: 1e6 },
        seed: 43,
        restart: quick_restart(2),
        inject: FaultPlan::parse("w0:hang@0").unwrap(),
        stall_budget_ms: Some(75.0),
    };
    let (got, rep) = run(&cfg, 47);
    assert_eq!(rep.hangs_detected, 1, "{:?}", rep.errors);
    // the injected hang parks on the cancel token, so the zombie
    // always wakes after the reap and reports in — and its stale
    // result is counted discarded, not delivered
    assert_eq!(
        rep.zombies_reaped, 1,
        "the woken zombie's result must be discarded via the \
         generation check"
    );
    assert_eq!(rep.incomplete, 0, "the stash reroute loses nothing");
    assert!(rep.errors.is_empty(), "{:?}", rep.errors);
    let mut delivered_total = 0;
    for (si, s) in rep.streams.iter().enumerate() {
        assert_eq!(s.meta.offered, 12);
        // terminal states partition offered frames: nothing counted
        // both dropped and delivered
        assert_eq!(
            s.meta.offered,
            s.delivered + s.meta.dropped + s.incomplete,
            "stream {si} accounting"
        );
        // strictly increasing indices == no frame delivered twice and
        // display order preserved across the reap
        let idx: Vec<usize> = got[si].iter().map(|(i, _)| *i).collect();
        assert!(
            idx.windows(2).all(|w| w[0] < w[1]),
            "stream {si} duplicated or reordered: {idx:?}"
        );
        assert_eq!(got[si].len(), s.delivered);
        delivered_total += s.delivered;
    }
    assert_eq!(rep.frames, delivered_total);
    assert!(
        rep.render().contains("zombie result"),
        "{}",
        rep.render()
    );
}

/// §Ladder bit-exactness: a x4 stream forced down the ladder serves
/// its `Reduced` frame as exactly "x2 SR model + bilinear expand" and
/// its `Bilinear` frames as exactly the pure bilinear path — verified
/// against offline references built from the same engine weights and
/// the same deterministic source.
#[test]
fn reduced_rung_is_bit_exact_against_offline_x2_plus_bilinear() {
    let (w, h, scale) = (8usize, 6usize, 4usize);
    let (base_seed, engine_seed) = (51u64, 9u64);
    let frames = 8;
    let cfg = MultiServeConfig {
        streams: vec![spec("a", w, h, scale)],
        frames,
        workers: 1,
        queue_depth: 1,
        // a deadline nothing can meet: frame 0 steps Full -> Reduced,
        // every later frame steps (or stays) at Bilinear
        policy: RtPolicy::Degrade { deadline_ms: 0.0 },
        seed: base_seed,
        restart: RestartPolicy::none(),
        inject: FaultPlan::default(),
        stall_budget_ms: None,
    };
    let (got, rep) = run(&cfg, engine_seed);
    assert_eq!(rep.frames, frames, "degrade never sheds");
    assert_eq!(
        rep.streams[0].degraded_by_level,
        [1, frames - 1],
        "one Reduced frame, the rest Bilinear"
    );
    // offline references: the x2 engine with the weights worker 0
    // would build for eng_scale=2, and the same synthetic source
    let mut x2 = Int8Engine::new(QuantModel::test_model(
        2, 3, 4, 2, engine_seed,
    ));
    let gen = SceneGenerator::new(w, h, stream_seed(base_seed, 0));
    for (fi, hr) in &got[0] {
        let lr = gen.frame(*fi);
        let want = if *fi == 0 {
            bilinear_upsample(&x2.upscale(&lr).unwrap(), scale / 2)
        } else {
            bilinear_upsample(&lr, scale)
        };
        assert_eq!(
            *hr, want,
            "frame {fi}: downshifted delivery must be bit-exact \
             against the offline reference"
        );
    }
}
