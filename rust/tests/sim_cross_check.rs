//! Simulator cross-checks (DESIGN.md §6): the cycle-exact PE-plane
//! stepping and the analytic closed form must agree on values AND
//! cycles; the paper-scale configuration must reproduce the published
//! utilization and frame-rate claims.

use sr_accel::config::AcceleratorConfig;
use sr_accel::fusion::TiltedScheduler;
use sr_accel::model::{PreparedLayer, QuantModel, Scratch, Tensor};
use sr_accel::sim::engine::{
    layer_cycles, AnalyticEngine, CycleExactEngine, EngineGeometry,
    TileEngine,
};
use sr_accel::util::quickcheck::{check_no_shrink, Config};
use sr_accel::util::Xoshiro256pp;

fn rand_patch(rows: usize, cols: usize, c: usize, seed: u64) -> Tensor<u8> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut p = Tensor::new(rows + 2, cols + 2, c);
    for y in 1..=rows {
        for x in 1..=cols {
            for ch in 0..c {
                p.set(y, x, ch, rng.next_u32() as u8);
            }
        }
    }
    p
}

#[test]
fn prop_engines_agree_over_random_layers() {
    let cfg = Config {
        cases: 30,
        seed: 0x5EED,
        max_shrink_iters: 0,
    };
    check_no_shrink(
        &cfg,
        |rng| {
            (
                rng.range_usize(1, 12),  // rows
                rng.range_usize(1, 9),   // cols
                rng.range_usize(1, 8),   // cin
                rng.range_usize(1, 8),   // cout
                rng.next_u64(),
            )
        },
        |&(rows, cols, cin, cout, seed)| {
            // hand-build a single ReLU layer with arbitrary cin/cout
            let mut rng = Xoshiro256pp::seed_from_u64(seed);
            let layer = sr_accel::model::QuantLayer {
                cin,
                cout,
                relu: true,
                s_in: 1.0 / 255.0,
                s_w: 0.01,
                s_out: 1.0 / 255.0,
                m: sr_accel::util::FixedMul::from_real(0.05),
                bias: (0..cout)
                    .map(|_| rng.range_u64(0, 200) as i32 - 100)
                    .collect(),
                w: (0..9 * cin * cout)
                    .map(|_| (rng.range_u64(0, 14) as i64 - 7) as i8)
                    .collect(),
            };
            let layer = PreparedLayer::new(&layer);
            let patch = rand_patch(rows, cols, cin, seed ^ 0xabc);
            let mut scratch = Scratch::new();
            let (a, ca) =
                AnalyticEngine::paper().run_layer(&patch, &layer, &mut scratch);
            let (c, cc) = CycleExactEngine::paper()
                .run_layer(&patch, &layer, &mut scratch);
            if a.unwrap_u8().data != c.unwrap_u8().data {
                return Err(format!(
                    "values differ at {rows}x{cols} {cin}->{cout}"
                ));
            }
            if ca != cc {
                return Err(format!("cycles differ: {ca:?} vs {cc:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn paper_config_reproduces_87_percent_utilization() {
    // APBN channels on the paper geometry, averaged over the 7 layers
    let geo = EngineGeometry::paper();
    let channels = [3usize, 28, 28, 28, 28, 28, 28, 27];
    let mut ops = 0u64;
    let mut slots = 0u64;
    for w in channels.windows(2) {
        let c = layer_cycles(60, 8, w[0], w[1], &geo);
        ops += c.mac_ops;
        slots += c.mac_slots;
    }
    let util = ops as f64 / slots as f64;
    assert!(
        (util - 0.87).abs() < 0.01,
        "average utilization {util:.3}, paper says 0.87"
    );
}

#[test]
fn paper_config_sustains_fhd_60fps() {
    // full-frame cycle count at the paper's design point must land
    // above 60 fps at 600 MHz (the paper's headline)
    let qm = QuantModel::test_model(7, 3, 28, 3, 0);
    let acc = AcceleratorConfig::paper();
    let frame = {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut t = Tensor::new(360, 640, 3);
        rng.fill_u8(&mut t.data);
        t
    };
    use sr_accel::fusion::FusionScheduler;
    let res = TiltedScheduler::default().run_frame(&frame, &qm, &acc);
    let fps = acc.frequency_mhz * 1e6 / res.stats.compute_cycles as f64;
    assert!(
        fps > 60.0,
        "paper design point must exceed 60 fps, got {fps:.1}"
    );
    assert!(
        fps < 80.0,
        "fps implausibly high ({fps:.1}) — cycle model broken?"
    );
    // utilization across the full frame matches the paper's average
    let util = res.stats.utilization();
    assert!(
        (util - 0.87).abs() < 0.02,
        "frame-level utilization {util:.3}"
    );
    // Mpix/s at the 60 fps target = the paper's 124.4
    let mpix_at_60: f64 = (1920.0 * 1080.0 * 60.0) / 1e6;
    assert!((mpix_at_60 - 124.4).abs() < 0.1);
}

#[test]
fn overlap_and_residual_budgets_match_paper_equations() {
    let qm = QuantModel::test_model(7, 3, 28, 3, 3);
    let acc = AcceleratorConfig::paper();
    let band = {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let mut t = Tensor::new(60, 64, 3);
        rng.fill_u8(&mut t.data);
        t
    };
    let (_, stats) = TiltedScheduler::default().run_band(&band, &qm, &acc);
    assert_eq!(stats.overlap_bytes, 30_240, "eq (2)");
    assert_eq!(stats.residual_bytes, 2_700, "eq (3)");
    assert!(stats.peak_pingpong_bytes <= 26_880, "eq (1) x2");
}

#[test]
fn dram_stall_model_kicks_in_for_layer_by_layer() {
    use sr_accel::analysis::comparison::frame_seconds;
    use sr_accel::fusion::{FusionScheduler, LayerByLayerScheduler};
    let qm = QuantModel::test_model(7, 3, 28, 3, 4);
    let acc = AcceleratorConfig::paper();
    let frame = {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut t = Tensor::new(120, 320, 3);
        rng.fill_u8(&mut t.data);
        t
    };
    let tilted = TiltedScheduler::default().run_frame(&frame, &qm, &acc);
    let lbl = LayerByLayerScheduler.run_frame(&frame, &qm, &acc);
    // same compute, hugely different DRAM -> layer-by-layer frame time
    // must be strictly worse once the channel saturates
    let t_tilted = frame_seconds(&tilted.stats, &acc);
    let t_lbl = frame_seconds(&lbl.stats, &acc);
    assert!(
        t_lbl > t_tilted,
        "layer-by-layer should be slower: {t_lbl} vs {t_tilted}"
    );
}
