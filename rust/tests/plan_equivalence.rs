//! §Planner acceptance: applying an execution plan must never change
//! output bits, and the tune → cache → serve loop must round-trip.
//!
//! The searched space is bit-preserving by construction (whole-frame,
//! or row bands with `HaloPolicy::Exact`, under either fused executor
//! — both already proven bit-identical by `shard_equivalence` and
//! `streaming_equivalence`); these tests pin that property end to end
//! through the planner's own enumeration, so a future widening of the
//! space cannot silently trade pixels for speed.

use std::path::PathBuf;

use sr_accel::coordinator::{
    run_pipeline, Engine, EngineFactory, Int8Engine, PipelineConfig,
};
use sr_accel::image::ImageU8;
use sr_accel::model::QuantModel;
use sr_accel::planner::{
    tune_serving, CachedPlan, Plan, PlanCache, PlanKey, SearchSpace,
    TuneParams,
};

fn factories(
    qm: &QuantModel,
    plan: &Plan,
    workers: usize,
) -> Vec<EngineFactory> {
    (0..workers)
        .map(|_| {
            let qm = qm.clone();
            let ex = plan.executor;
            Box::new(move || {
                // clone *inside*: the supervisor may call the factory
                // again after a restart
                Ok(Box::new(Int8Engine::with_executor(qm.clone(), ex))
                    as Box<dyn Engine>)
            }) as EngineFactory
        })
        .collect()
}

fn run_plan(
    qm: &QuantModel,
    lr_w: usize,
    lr_h: usize,
    plan: &Plan,
    workers: usize,
) -> Vec<ImageU8> {
    let cfg = PipelineConfig {
        frames: 2,
        queue_depth: 2,
        workers,
        lr_w,
        lr_h,
        seed: 13,
        source_fps: None,
        scale: qm.scale,
        shard: plan.shard.clone(),
        model_layers: qm.n_layers(),
        restart: sr_accel::config::RestartPolicy::none(),
        stall_budget_ms: None,
        inject: sr_accel::coordinator::FaultPlan::default(),
    };
    let mut out = Vec::new();
    run_pipeline(&cfg, factories(qm, plan, workers), |_, hr| {
        out.push(hr.clone())
    })
    .expect("pipeline run failed");
    out
}

/// Every plan the serving search space can propose produces frames
/// bit-identical to the serving default.
#[test]
fn every_candidate_plan_is_bit_identical_to_default() {
    let workers = 2;
    let qm = QuantModel::test_model(2, 3, 4, 3, 17);
    let (lr_w, lr_h) = (24usize, 18usize);
    let baseline = run_plan(&qm, lr_w, lr_h, &Plan::serving_default(), workers);
    assert_eq!(baseline.len(), 2);
    let plans = SearchSpace::serving(lr_h, workers).enumerate();
    assert!(plans.len() >= 4, "serving space degenerated: {plans:?}");
    for plan in &plans {
        let got = run_plan(&qm, lr_w, lr_h, plan, workers);
        assert_eq!(
            got,
            baseline,
            "plan changed output bits: {}",
            plan.describe()
        );
    }
}

/// Same property on an odd geometry and scale through the smoke space
/// (the exact space `tune --smoke` / CI searches).
#[test]
fn smoke_space_is_bit_preserving_on_odd_geometry() {
    let workers = 3;
    let qm = QuantModel::test_model(3, 3, 5, 2, 23);
    let (lr_w, lr_h) = (19usize, 13usize);
    let baseline = run_plan(&qm, lr_w, lr_h, &Plan::serving_default(), workers);
    for plan in &SearchSpace::smoke(lr_h, workers).enumerate() {
        let got = run_plan(&qm, lr_w, lr_h, plan, workers);
        assert_eq!(
            got,
            baseline,
            "plan changed output bits: {}",
            plan.describe()
        );
    }
}

fn temp_cache(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "sr-accel-plan-eq-{}-{tag}.toml",
        std::process::id()
    ))
}

/// The full loop: tune on the real engine, persist the winner, reload
/// the cache, and apply the plan — output stays bit-identical and the
/// recorded speedup can never undercut the default.
#[test]
fn tune_cache_serve_roundtrip() {
    let workers = 2;
    let qm = QuantModel::test_model(2, 3, 4, 3, 29);
    let (lr_w, lr_h) = (20usize, 14usize);
    let key = PlanKey::detected(lr_w, lr_h, qm.scale, workers);
    let space = SearchSpace::smoke(lr_h, workers);
    let params = TuneParams {
        top_k: 2,
        confirm_frames: 2,
        confirm_reps: 1,
        seed: 13,
    };
    let res = tune_serving(&qm, key.clone(), &space, &params)
        .expect("tuning failed");
    assert!(
        res.plan_speedup() >= 1.0,
        "winner must be the measured argmax: {}",
        res.plan_speedup()
    );
    let wc = &res.candidates[res.winner];
    assert!(wc.measured_mpix_s.unwrap_or(0.0) > 0.0);

    // persist -> reload -> exact-key hit, foreign-key miss
    let path = temp_cache("roundtrip");
    let _ = std::fs::remove_file(&path);
    let mut cache = PlanCache::new();
    cache.insert(CachedPlan {
        key: key.clone(),
        plan: wc.plan.clone(),
        predicted_score: wc.predicted.score,
        measured_mpix_s: wc.measured_mpix_s.unwrap_or(0.0),
    });
    cache.save(&path).expect("cache save failed");
    let loaded = PlanCache::load(&path);
    let hit = loaded.lookup(&key).expect("exact key must hit");
    assert_eq!(hit.plan, wc.plan);
    let other_workers = PlanKey::new(
        lr_w,
        lr_h,
        qm.scale,
        &key.isa,
        workers + 1,
    );
    assert!(
        loaded.lookup(&other_workers).is_none(),
        "a plan tuned for {} workers must not serve {}",
        workers,
        workers + 1
    );
    let other_isa =
        PlanKey::new(lr_w, lr_h, qm.scale, "other-isa", workers);
    assert!(loaded.lookup(&other_isa).is_none());

    // applying the cached winner changes no pixels
    let baseline = run_plan(&qm, lr_w, lr_h, &Plan::serving_default(), workers);
    let tuned = run_plan(&qm, lr_w, lr_h, &hit.plan, workers);
    assert_eq!(tuned, baseline, "cached plan changed output bits");
    let _ = std::fs::remove_file(&path);
}
