//! §Microkernel equivalence properties: every compiled-in ISA kernel
//! (AVX-512 / AVX2 / NEON, whichever this host can run) must be
//! **bit-identical** to the scalar oracle and to a naive direct
//! convolution written independently here — across randomized
//! geometries and a deterministic sweep of every masked-tail case:
//! `width % P` in `{0..P-1}` for every strip width up to `MK_P_MAX`,
//! `cout` crossing both the 8-lane and 16-lane tile boundaries
//! (padded lanes), odd `cin` (the zero-weight pair half), and both
//! epilogues (fused ReLU/saturate u8 and final-layer i32).  The
//! auto-dispatch entry points (`Isa::select`) and the frozen PR-2
//! pixel kernels (`reference::baseline`) are pinned to the same
//! oracle so the benches' `microkernel_speedup` compares two correct
//! kernels.

use sr_accel::model::{
    PreparedLayer, PreparedModel, QuantLayer, QuantModel, Scratch, Tensor,
};
use sr_accel::reference::conv::{
    conv3x3_final_impl, conv3x3_final_isa, conv3x3_relu_impl,
    conv3x3_relu_isa, conv_patch_final_impl, conv_patch_final_isa,
    conv_patch_relu_impl, conv_patch_relu_isa,
};
use sr_accel::reference::{self, baseline, Isa, MK_P_MAX};
use sr_accel::util::fixed::{clamp_u8, FixedMul};
use sr_accel::util::quickcheck::{check_no_shrink, Config};
use sr_accel::util::Xoshiro256pp;

fn rand_layer(cin: usize, cout: usize, relu: bool, seed: u64) -> QuantLayer {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    QuantLayer {
        cin,
        cout,
        relu,
        s_in: 1.0 / 255.0,
        s_w: 0.01,
        s_out: 1.0 / 255.0,
        m: FixedMul::from_real(0.05),
        bias: (0..cout)
            .map(|_| rng.range_u64(0, 200) as i32 - 100)
            .collect(),
        w: (0..9 * cin * cout)
            .map(|_| (rng.range_u64(0, 255) as i64 - 128) as i8)
            .collect(),
    }
}

fn rand_map(h: usize, w: usize, c: usize, seed: u64) -> Tensor<u8> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut t = Tensor::new(h, w, c);
    rng.fill_u8(&mut t.data);
    // sprinkle zeros so the sparsity-skip branches are exercised
    for i in (0..t.data.len()).step_by(7) {
        t.data[i] = 0;
    }
    t
}

/// Independent oracle: direct SAME 3x3 conv, no packing, no scratch.
fn naive_conv3x3(x: &Tensor<u8>, l: &QuantLayer) -> (Vec<u8>, Vec<i32>) {
    let mut out_u8 = vec![0u8; x.h * x.w * l.cout];
    let mut out_i32 = vec![0i32; x.h * x.w * l.cout];
    for y in 0..x.h {
        for xx in 0..x.w {
            for co in 0..l.cout {
                let mut acc: i32 = l.bias[co];
                for dr in 0..3usize {
                    for dc in 0..3usize {
                        let sy = y as isize + dr as isize - 1;
                        let sx = xx as isize + dc as isize - 1;
                        if sy < 0
                            || sy >= x.h as isize
                            || sx < 0
                            || sx >= x.w as isize
                        {
                            continue;
                        }
                        for ci in 0..l.cin {
                            let xv = x.get(sy as usize, sx as usize, ci)
                                as i32;
                            acc += xv
                                * l.weight(dr, dc, ci, co) as i32;
                        }
                    }
                }
                let q = l.m.apply(acc as i64);
                out_u8[(y * x.w + xx) * l.cout + co] = clamp_u8(q);
                out_i32[(y * x.w + xx) * l.cout + co] = q as i32;
            }
        }
    }
    (out_u8, out_i32)
}

/// Zero-halo patch so the VALID patch kernels compute the SAME conv.
fn zero_halo_patch(x: &Tensor<u8>) -> Tensor<u8> {
    let mut p: Tensor<u8> = Tensor::new(x.h + 2, x.w + 2, x.c);
    for y in 0..x.h {
        for xx in 0..x.w {
            for c in 0..x.c {
                p.set(y + 1, xx + 1, c, x.get(y, xx, c));
            }
        }
    }
    p
}

/// Every compiled-in ISA this host can run, scalar oracle first.
fn runnable_isas() -> Vec<Isa> {
    Isa::compiled()
        .into_iter()
        .filter(|i| i.available())
        .collect()
}

/// Both conv paths (row SAME, patch VALID), every runnable ISA plus
/// both auto dispatches (`Isa::select(force_scalar)`), one epilogue —
/// all against the naive oracle.
fn assert_all_paths(
    x: &Tensor<u8>,
    l: &QuantLayer,
    scratch: &mut Scratch,
    label: &str,
) -> Result<(), String> {
    let pl = PreparedLayer::new(l);
    let (want_u8, want_i32) = naive_conv3x3(x, l);
    let patch = zero_halo_patch(x);
    if l.relu {
        for isa in runnable_isas() {
            let row = conv3x3_relu_isa(x, &pl, scratch, isa);
            if row.data != want_u8 {
                return Err(format!(
                    "{label}: row relu diverged (isa={})",
                    isa.name()
                ));
            }
            scratch.recycle_u8(row);
            let pat = conv_patch_relu_isa(&patch, &pl, scratch, isa);
            if pat.data != want_u8 {
                return Err(format!(
                    "{label}: patch relu diverged (isa={})",
                    isa.name()
                ));
            }
            scratch.recycle_u8(pat);
        }
        // the public auto-dispatch entries must agree with the
        // per-ISA sweep on both routes
        for force_scalar in [false, true] {
            let row = conv3x3_relu_impl(x, &pl, scratch, force_scalar);
            if row.data != want_u8 {
                return Err(format!(
                    "{label}: row relu diverged (scalar={force_scalar})"
                ));
            }
            scratch.recycle_u8(row);
            let pat =
                conv_patch_relu_impl(&patch, &pl, scratch, force_scalar);
            if pat.data != want_u8 {
                return Err(format!(
                    "{label}: patch relu diverged (scalar={force_scalar})"
                ));
            }
            scratch.recycle_u8(pat);
        }
        // the frozen PR-2 pixel kernels are the measured speedup
        // baseline: pin them to the same oracle
        let bl_row = baseline::conv3x3_relu_pixel(x, &pl, scratch);
        if bl_row.data != want_u8 {
            return Err(format!("{label}: baseline row relu diverged"));
        }
        scratch.recycle_u8(bl_row);
        let bl_pat = baseline::conv_patch_relu_pixel(&patch, &pl, scratch);
        if bl_pat.data != want_u8 {
            return Err(format!("{label}: baseline patch relu diverged"));
        }
        scratch.recycle_u8(bl_pat);
    } else {
        for isa in runnable_isas() {
            let row = conv3x3_final_isa(x, &pl, scratch, isa);
            if row.data != want_i32 {
                return Err(format!(
                    "{label}: row final diverged (isa={})",
                    isa.name()
                ));
            }
            scratch.recycle_i32(row);
            let pat = conv_patch_final_isa(&patch, &pl, scratch, isa);
            if pat.data != want_i32 {
                return Err(format!(
                    "{label}: patch final diverged (isa={})",
                    isa.name()
                ));
            }
            scratch.recycle_i32(pat);
        }
        for force_scalar in [false, true] {
            let row = conv3x3_final_impl(x, &pl, scratch, force_scalar);
            if row.data != want_i32 {
                return Err(format!(
                    "{label}: row final diverged (scalar={force_scalar})"
                ));
            }
            scratch.recycle_i32(row);
            let pat =
                conv_patch_final_impl(&patch, &pl, scratch, force_scalar);
            if pat.data != want_i32 {
                return Err(format!(
                    "{label}: patch final diverged (scalar={force_scalar})"
                ));
            }
            scratch.recycle_i32(pat);
        }
        let bl_row = baseline::conv3x3_final_pixel(x, &pl, scratch);
        if bl_row.data != want_i32 {
            return Err(format!("{label}: baseline row final diverged"));
        }
        scratch.recycle_i32(bl_row);
        let bl_pat =
            baseline::conv_patch_final_pixel(&patch, &pl, scratch);
        if bl_pat.data != want_i32 {
            return Err(format!("{label}: baseline patch final diverged"));
        }
        scratch.recycle_i32(bl_pat);
    }
    Ok(())
}

#[test]
fn strip_tail_sweep_covers_every_mask() {
    // deterministic coverage: every width remainder mod P for every
    // compiled strip width (4-wide AVX2/NEON, 6-wide AVX-512), odd
    // cin, cout crossing the 8- and 16-lane tile boundaries, both
    // epilogues, on one shared scratch
    let mut scratch = Scratch::new();
    for w in 1..=2 * MK_P_MAX + 1 {
        for &(cin, cout) in &[
            (1usize, 4usize),
            (3, 8),
            (4, 11),
            (5, 16),
            (6, 17),
            (3, 24),
            (7, 20),
            (2, 32),
        ] {
            for relu in [true, false] {
                let seed = (w * 1009 + cin * 31 + cout * 7) as u64
                    + relu as u64;
                let l = rand_layer(cin, cout, relu, seed);
                let x = rand_map(5, w, cin, seed ^ 0xA5A5);
                let label = format!(
                    "w={w} (w%Pmax={}) {cin}->{cout} relu={relu}",
                    w % MK_P_MAX
                );
                if let Err(e) =
                    assert_all_paths(&x, &l, &mut scratch, &label)
                {
                    panic!("{e}");
                }
            }
        }
    }
}

#[test]
fn prop_microkernel_matches_scalar_and_naive() {
    let cfg = Config {
        cases: 50,
        seed: 0x5712,
        max_shrink_iters: 0,
    };
    let mut scratch = Scratch::new();
    check_no_shrink(
        &cfg,
        |rng| {
            (
                rng.range_usize(1, 10),  // h
                rng.range_usize(1, 14),  // w (crosses MK_P boundaries)
                rng.range_usize(1, 10),  // cin (odd values included)
                rng.range_usize(1, 20),  // cout (rarely divisible by 8)
                rng.next_u64() & 1 == 0, // relu
                rng.next_u64(),
            )
        },
        |&(h, w, cin, cout, relu, seed)| {
            let l = rand_layer(cin, cout, relu, seed);
            let x = rand_map(h, w, cin, seed ^ 0x77);
            assert_all_paths(
                &x,
                &l,
                &mut scratch,
                &format!("{h}x{w} {cin}->{cout} relu={relu}"),
            )
        },
    );
}

#[test]
fn fused_epilogue_saturates_like_the_silicon() {
    // huge positive bias must clamp to 255 in the fused ReLU epilogue,
    // huge negative to 0, and the final layer must pass i32 through
    // unclamped — on every runnable ISA and on both auto dispatches
    let mut scratch = Scratch::new();
    for bias in [1 << 20, -(1 << 20)] {
        let mut l = rand_layer(3, 9, true, 3);
        l.bias.iter_mut().for_each(|b| *b = bias);
        l.m = FixedMul {
            m0: 1 << sr_accel::util::fixed::SHIFT,
        };
        let pl = PreparedLayer::new(&l);
        let x = Tensor::new(4, 5, 3); // zero input: output = requant(bias)
        let want = if bias > 0 { 255 } else { 0 };
        for isa in runnable_isas() {
            let y = conv3x3_relu_isa(&x, &pl, &mut scratch, isa);
            assert!(
                y.data.iter().all(|&v| v == want),
                "bias {bias} isa={}",
                isa.name()
            );
            scratch.recycle_u8(y);
        }
        for force_scalar in [false, true] {
            let y = conv3x3_relu_impl(&x, &pl, &mut scratch, force_scalar);
            assert!(
                y.data.iter().all(|&v| v == want),
                "bias {bias} scalar={force_scalar}"
            );
            scratch.recycle_u8(y);
        }
        let mut lf = l.clone();
        lf.relu = false;
        let plf = PreparedLayer::new(&lf);
        for isa in runnable_isas() {
            let y = conv3x3_final_isa(&x, &plf, &mut scratch, isa);
            assert!(
                y.data.iter().all(|&v| v == bias),
                "final bias {bias} isa={}",
                isa.name()
            );
            scratch.recycle_i32(y);
        }
        for force_scalar in [false, true] {
            let y = conv3x3_final_impl(&x, &plf, &mut scratch, force_scalar);
            assert!(
                y.data.iter().all(|&v| v == bias),
                "final bias {bias} scalar={force_scalar}"
            );
            scratch.recycle_i32(y);
        }
    }
}

#[test]
fn whole_model_forward_pinned_to_pr2_baseline() {
    // microkernel forward == frozen PR-2 pixel forward, whole model,
    // awkward channel counts, shared scratch across frames
    for (n_layers, c_in, c_mid, scale, seed) in [
        (3usize, 3usize, 5usize, 3usize, 1u64),
        (2, 1, 7, 2, 2),
        (4, 3, 9, 3, 3),
    ] {
        let qm = QuantModel::test_model(n_layers, c_in, c_mid, scale, seed);
        let pm = PreparedModel::new(&qm);
        let mut scratch = Scratch::new();
        for frame_seed in 0..3u64 {
            let x = rand_map(9, 11, c_in, 100 + frame_seed);
            let want = reference::forward_int(&x, &qm);
            let got = reference::forward_int_prepared(&x, &pm, &mut scratch);
            assert_eq!(
                got.data, want.data,
                "microkernel forward, model {n_layers}l frame {frame_seed}"
            );
            let pixel = baseline::forward_int_pixel(&x, &pm, &mut scratch);
            assert_eq!(
                pixel.data, got.data,
                "PR-2 baseline forward, model {n_layers}l frame {frame_seed}"
            );
        }
    }
}
