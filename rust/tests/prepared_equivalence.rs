//! §Perf equivalence properties: the prepared-weight execution paths
//! (packed once per model, scratch reused across calls) must be
//! **bit-identical** to the legacy pack-per-call paths and to a naive
//! direct convolution written independently here — across randomized
//! `cin`/`cout`/geometry, explicitly including odd `cin` and `cout`
//! not divisible by 8 (the padded-lane edge cases).  Where the host has
//! AVX2, the vector and scalar kernels are additionally pinned against
//! each other via the `force_scalar` dispatch override.

use sr_accel::model::{
    PreparedLayer, PreparedModel, QuantLayer, QuantModel, Scratch, Tensor,
};
use sr_accel::reference::{
    self, conv3x3_final, conv3x3_relu, conv_patch_final, conv_patch_relu,
};
use sr_accel::reference::conv::{
    conv3x3_final_impl, conv3x3_relu_impl, conv_patch_final_impl,
    conv_patch_relu_impl,
};
use sr_accel::util::fixed::clamp_u8;
use sr_accel::util::quickcheck::{check_no_shrink, Config};
use sr_accel::util::{FixedMul, Xoshiro256pp};

fn rand_layer(cin: usize, cout: usize, relu: bool, seed: u64) -> QuantLayer {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    QuantLayer {
        cin,
        cout,
        relu,
        s_in: 1.0 / 255.0,
        s_w: 0.01,
        s_out: 1.0 / 255.0,
        m: FixedMul::from_real(0.05),
        bias: (0..cout)
            .map(|_| rng.range_u64(0, 200) as i32 - 100)
            .collect(),
        w: (0..9 * cin * cout)
            .map(|_| (rng.range_u64(0, 255) as i64 - 128) as i8)
            .collect(),
    }
}

fn rand_map(h: usize, w: usize, c: usize, seed: u64) -> Tensor<u8> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut t = Tensor::new(h, w, c);
    rng.fill_u8(&mut t.data);
    // sprinkle zeros so the sparsity-skip branches are exercised
    for i in (0..t.data.len()).step_by(7) {
        t.data[i] = 0;
    }
    t
}

/// Independent oracle: direct SAME 3x3 conv, no packing, no scratch.
fn naive_conv3x3(x: &Tensor<u8>, l: &QuantLayer) -> (Vec<u8>, Vec<i32>) {
    let mut out_u8 = vec![0u8; x.h * x.w * l.cout];
    let mut out_i32 = vec![0i32; x.h * x.w * l.cout];
    for y in 0..x.h {
        for xx in 0..x.w {
            for co in 0..l.cout {
                let mut acc: i32 = l.bias[co];
                for dr in 0..3usize {
                    for dc in 0..3usize {
                        let sy = y as isize + dr as isize - 1;
                        let sx = xx as isize + dc as isize - 1;
                        if sy < 0
                            || sy >= x.h as isize
                            || sx < 0
                            || sx >= x.w as isize
                        {
                            continue;
                        }
                        for ci in 0..l.cin {
                            let xv = x.get(sy as usize, sx as usize, ci)
                                as i32;
                            acc += xv
                                * l.weight(dr, dc, ci, co) as i32;
                        }
                    }
                }
                let q = l.m.apply(acc as i64);
                out_u8[(y * x.w + xx) * l.cout + co] = clamp_u8(q);
                out_i32[(y * x.w + xx) * l.cout + co] = q as i32;
            }
        }
    }
    (out_u8, out_i32)
}

/// Zero-halo patch so the VALID patch kernels compute the SAME conv.
fn zero_halo_patch(x: &Tensor<u8>) -> Tensor<u8> {
    let mut p: Tensor<u8> = Tensor::new(x.h + 2, x.w + 2, x.c);
    for y in 0..x.h {
        for xx in 0..x.w {
            for c in 0..x.c {
                p.set(y + 1, xx + 1, c, x.get(y, xx, c));
            }
        }
    }
    p
}

fn geometry_gen(rng: &mut Xoshiro256pp) -> (usize, usize, usize, usize, u64) {
    (
        rng.range_usize(1, 10),  // h
        rng.range_usize(1, 12),  // w
        rng.range_usize(1, 10),  // cin (odd values included)
        rng.range_usize(1, 20),  // cout (rarely divisible by 8)
        rng.next_u64(),
    )
}

#[test]
fn prop_prepared_relu_matches_naive_and_legacy() {
    let cfg = Config {
        cases: 40,
        seed: 0xBEEF,
        max_shrink_iters: 0,
    };
    // one scratch across all cases: reuse must never leak state
    let mut scratch = Scratch::new();
    check_no_shrink(&cfg, geometry_gen, |&(h, w, cin, cout, seed)| {
        let l = rand_layer(cin, cout, true, seed);
        let pl = PreparedLayer::new(&l);
        let x = rand_map(h, w, cin, seed ^ 0x55);
        let (want, _) = naive_conv3x3(&x, &l);

        let legacy = conv3x3_relu(&x, &l);
        if legacy.data != want {
            return Err(format!(
                "legacy row path diverged at {h}x{w} {cin}->{cout}"
            ));
        }
        let scalar = conv3x3_relu_impl(&x, &pl, &mut scratch, true);
        if scalar.data != want {
            return Err(format!(
                "prepared scalar diverged at {h}x{w} {cin}->{cout}"
            ));
        }
        let auto = conv3x3_relu_impl(&x, &pl, &mut scratch, false);
        if auto.data != want {
            return Err(format!(
                "prepared dispatch (AVX2 if present) diverged at \
                 {h}x{w} {cin}->{cout}"
            ));
        }
        scratch.recycle_u8(scalar);
        scratch.recycle_u8(auto);
        Ok(())
    });
}

#[test]
fn prop_prepared_patch_matches_legacy_patch() {
    let cfg = Config {
        cases: 40,
        seed: 0xF00D,
        max_shrink_iters: 0,
    };
    let mut scratch = Scratch::new();
    check_no_shrink(&cfg, geometry_gen, |&(h, w, cin, cout, seed)| {
        let l = rand_layer(cin, cout, true, seed);
        let pl = PreparedLayer::new(&l);
        let x = rand_map(h, w, cin, seed ^ 0x99);
        let patch = zero_halo_patch(&x);

        let legacy = conv_patch_relu(&patch, &l);
        let scalar = conv_patch_relu_impl(&patch, &pl, &mut scratch, true);
        if scalar.data != legacy.data {
            return Err(format!(
                "prepared patch scalar diverged at {h}x{w} {cin}->{cout}"
            ));
        }
        let auto = conv_patch_relu_impl(&patch, &pl, &mut scratch, false);
        if auto.data != legacy.data {
            return Err(format!(
                "prepared patch dispatch diverged at {h}x{w} {cin}->{cout}"
            ));
        }
        scratch.recycle_u8(scalar);
        scratch.recycle_u8(auto);
        Ok(())
    });
}

#[test]
fn prop_prepared_final_layer_matches() {
    let cfg = Config {
        cases: 30,
        seed: 0xD00D,
        max_shrink_iters: 0,
    };
    let mut scratch = Scratch::new();
    check_no_shrink(&cfg, geometry_gen, |&(h, w, cin, cout, seed)| {
        let l = rand_layer(cin, cout, false, seed);
        let pl = PreparedLayer::new(&l);
        let x = rand_map(h, w, cin, seed ^ 0x33);
        let (_, want) = naive_conv3x3(&x, &l);

        let legacy = conv3x3_final(&x, &l);
        if legacy.data != want {
            return Err("legacy final row path diverged".into());
        }
        for force_scalar in [true, false] {
            let got = conv3x3_final_impl(&x, &pl, &mut scratch, force_scalar);
            if got.data != want {
                return Err(format!(
                    "prepared final (force_scalar={force_scalar}) \
                     diverged at {h}x{w} {cin}->{cout}"
                ));
            }
            scratch.recycle_i32(got);
        }
        let patch = zero_halo_patch(&x);
        let legacy_patch = conv_patch_final(&patch, &l);
        if legacy_patch.data != want {
            return Err("legacy final patch path diverged".into());
        }
        for force_scalar in [true, false] {
            let got =
                conv_patch_final_impl(&patch, &pl, &mut scratch, force_scalar);
            if got.data != want {
                return Err(format!(
                    "prepared final patch (force_scalar={force_scalar}) \
                     diverged at {h}x{w} {cin}->{cout}"
                ));
            }
            scratch.recycle_i32(got);
        }
        Ok(())
    });
}

#[test]
fn prepared_full_model_forward_is_bit_identical() {
    // whole-model check over awkward channel counts (odd cin, cout % 8
    // != 0 in the trunk and the x3 shuffle tail)
    for (n_layers, c_in, c_mid, scale, seed) in
        [(3usize, 3usize, 5usize, 3usize, 1u64), (2, 1, 7, 2, 2), (4, 3, 9, 3, 3)]
    {
        let qm = QuantModel::test_model(n_layers, c_in, c_mid, scale, seed);
        let pm = PreparedModel::new(&qm);
        let mut scratch = Scratch::new();
        for frame_seed in 0..3u64 {
            let x = rand_map(9, 11, c_in, 100 + frame_seed);
            let want = reference::forward_int(&x, &qm);
            let got = reference::forward_int_prepared(&x, &pm, &mut scratch);
            assert_eq!(
                got.data, want.data,
                "model {n_layers}l c{c_in}->{c_mid} x{scale} frame {frame_seed}"
            );
        }
    }
}
