//! Quickstart: load the AOT artifacts, upscale one synthetic image with
//! both engines (bit-exact int8 and PJRT float), compare, and write the
//! results as PPM files.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::path::Path;

use anyhow::Result;

use sr_accel::coordinator::{Engine, Int8Engine, PjrtEngine};
use sr_accel::image::{psnr_u8, write_ppm, SceneGenerator};
use sr_accel::model::load_apbnw;
use sr_accel::runtime::artifacts_dir;

fn main() -> Result<()> {
    // 1. weights (quantized by the Python compile path)
    let qm = load_apbnw(&artifacts_dir().join("weights.apbnw"))?;
    println!(
        "model: {} layers, channels {:?}, {} int8 weights",
        qm.n_layers(),
        qm.channels(),
        qm.weight_bytes()
    );

    // 2. one synthetic LR frame at the PJRT tile geometry
    let lr = SceneGenerator::new(32, 24, 42).frame(0);
    write_ppm(Path::new("/tmp/quickstart_lr.ppm"), &lr)?;

    // 3. the integer engine (the silicon's arithmetic)
    let mut int8 = Int8Engine::new(qm);
    let hr_int8 = int8.upscale(&lr)?;
    write_ppm(Path::new("/tmp/quickstart_int8.ppm"), &hr_int8)?;
    println!("int8: {}x{} -> {}x{}", lr.w, lr.h, hr_int8.w, hr_int8.h);

    // 4. the PJRT engine (AOT-lowered JAX float model)
    let mut pjrt = PjrtEngine::from_artifact("apbn_tile.hlo.txt")?;
    let hr_pjrt = pjrt.upscale(&lr)?;
    write_ppm(Path::new("/tmp/quickstart_pjrt.ppm"), &hr_pjrt)?;

    // 5. the two datapaths agree up to quantization error
    let p = psnr_u8(&hr_int8, &hr_pjrt);
    println!("int8 vs pjrt (float) PSNR: {p:.1} dB (quantization gap)");
    assert!(p > 40.0, "engines diverged: {p:.1} dB");
    println!("wrote /tmp/quickstart_{{lr,int8,pjrt}}.ppm");
    Ok(())
}
