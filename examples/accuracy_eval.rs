//! E5 — accuracy evaluation: the tilted-fusion banding penalty and the
//! int8 quantization penalty, measured against ground truth on a
//! synthetic Set5-like eval set (the Rust-side counterpart of
//! `python/tests/test_tilted.py`).
//!
//! ```sh
//! make artifacts && cargo run --release --example accuracy_eval
//! ```

use anyhow::Result;

use sr_accel::benchkit::Table;
use sr_accel::config::AcceleratorConfig;
use sr_accel::coordinator::{Engine, Int8Engine, SimEngine};
use sr_accel::image::{box_downsample_x3, psnr_u8, ImageU8, SceneGenerator};
use sr_accel::model::load_apbnw;
use sr_accel::runtime::artifacts_dir;

fn main() -> Result<()> {
    let qm = load_apbnw(&artifacts_dir().join("weights.apbnw"))?;
    let acc = AcceleratorConfig::paper(); // 60-row bands
    let mut t = Table::new(
        "accuracy on synthetic scenes (HR 360x480, LR 120x160, x3)",
        &[
            "scene", "monolithic PSNR dB", "banded PSNR dB",
            "penalty dB", "nearest-anchor dB",
        ],
    );
    let mut worst_penalty = 0.0f64;
    for seed in 0..5u64 {
        // ground truth HR scene and its box-downsampled LR
        let hr_gt = SceneGenerator::new(480, 360, 100 + seed).frame(0);
        let lr_f = box_downsample_x3(&hr_gt.to_f32());
        let lr = lr_f.to_u8();

        let mut mono = Int8Engine::new(qm.clone());
        let hr_mono = mono.upscale(&lr)?;
        let mut banded = SimEngine::new(qm.clone(), acc.clone());
        let hr_band = banded.upscale(&lr)?;
        let anchor = sr_accel::image::nearest_upsample(&lr, 3);

        let p_mono = psnr_u8(&hr_mono, &hr_gt);
        let p_band = psnr_u8(&hr_band, &hr_gt);
        let p_anchor = psnr_u8(&anchor, &hr_gt);
        let pen = p_mono - p_band;
        worst_penalty = worst_penalty.max(pen);
        t.row(&[
            format!("scene {seed}"),
            format!("{p_mono:.2}"),
            format!("{p_band:.2}"),
            format!("{pen:.3}"),
            format!("{p_anchor:.2}"),
        ]);
    }
    t.print();
    println!(
        "\nworst banding penalty: {worst_penalty:.3} dB \
         (paper: < 0.2 dB from their simulation)"
    );
    assert!(
        worst_penalty < 0.2,
        "banding penalty exceeded the paper's bound"
    );
    // visual artifact for inspection
    let hr_gt = SceneGenerator::new(480, 360, 100).frame(0);
    let lr = box_downsample_x3(&hr_gt.to_f32()).to_u8();
    let mut eng = SimEngine::new(qm, acc);
    let out = eng.upscale(&lr)?;
    sr_accel::image::write_ppm(
        std::path::Path::new("/tmp/accuracy_banded.ppm"),
        &out,
    )?;
    let _: &ImageU8 = &out;
    println!("wrote /tmp/accuracy_banded.ppm");
    Ok(())
}
