//! E7 — the end-to-end driver (DESIGN.md §4): serve a synthetic 640x360
//! video stream through the coordinator at the paper's geometry, with
//! BOTH the native int8 engine and the hardware simulator, and report
//! throughput/latency plus the simulated silicon's fps.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_video
//! ```
//!
//! The run is recorded in EXPERIMENTS.md (E7).

use anyhow::Result;

use sr_accel::config::{AcceleratorConfig, HaloPolicy, ShardPlan};
use sr_accel::coordinator::{
    run_pipeline, Engine, EngineFactory, Int8Engine, PipelineConfig,
    SimEngine,
};
use sr_accel::model::load_apbnw;
use sr_accel::runtime::artifacts_dir;

fn main() -> Result<()> {
    let qm = load_apbnw(&artifacts_dir().join("weights.apbnw"))?;

    // ---- 1. host serving: int8 engine on 320x180 (quarter frames,
    //         keeps the demo quick on a 1-core CI host), band-sharded
    //         across two workers with exact halos -------------------
    let workers = 2;
    let cfg = PipelineConfig {
        frames: 12,
        queue_depth: 4,
        workers,
        lr_w: 320,
        lr_h: 180,
        seed: 7,
        source_fps: None,
        scale: 3,
        shard: ShardPlan::row_bands(45, HaloPolicy::Exact),
        model_layers: qm.n_layers(),
    };
    let factories: Vec<EngineFactory> = (0..workers)
        .map(|_| {
            let qmc = qm.clone();
            Box::new(move || {
                Ok(Box::new(Int8Engine::new(qmc)) as Box<dyn Engine>)
            }) as EngineFactory
        })
        .collect();
    println!("== host serving (int8 engine, 320x180 LR, band-sharded) ==");
    let rep = run_pipeline(&cfg, factories, |_, _| {})?;
    println!("{}\n", rep.render());

    // ---- 2. silicon-side: the tilted-fusion simulator on one full
    //         640x360 frame, reporting the modeled chip fps -----------
    println!("== simulated silicon (tilted fusion, 640x360 LR) ==");
    let acc = AcceleratorConfig::paper();
    let mut sim = SimEngine::new(qm, acc.clone());
    let frame = sr_accel::image::SceneGenerator::paper_lr(7).frame(0);
    let t0 = std::time::Instant::now();
    let hr = sim.upscale(&frame)?;
    let wall = t0.elapsed();
    let stats = sim.last_stats().unwrap();
    let chip_fps =
        acc.frequency_mhz * 1e6 / stats.compute_cycles as f64;
    println!(
        "HR {}x{}; {} cycles/frame -> {:.1} fps at {} MHz \
         (paper: 60 fps), PE util {:.1} % (paper: 87 %)",
        hr.w,
        hr.h,
        stats.compute_cycles,
        chip_fps,
        acc.frequency_mhz,
        stats.utilization() * 100.0
    );
    println!(
        "DRAM: {:.2} MB/frame -> {:.2} GB/s at 60 fps (paper: 0.41)",
        stats.dram_total_bytes() as f64 / 1e6,
        stats.dram_total_bytes() as f64 * 60.0 / 1e9
    );
    println!("(simulator wall time {:.1} s)", wall.as_secs_f64());
    assert!(chip_fps > 60.0, "silicon model must sustain 60 fps");
    Ok(())
}
