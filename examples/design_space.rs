//! Design-space exploration: enumerate the planner's schedule space
//! and map the SRAM / throughput / utilization frontier the paper's
//! Section IV.A argues about.  Shows why (C=8, R=60, 28 blocks) is the
//! published design point.
//!
//! The schedule tables here and the `tune` subcommand share one
//! enumeration + cost model (`sr_accel::planner`) — this example is a
//! thin ablation printer over it, with no wall-clock confirmation.
//!
//! ```sh
//! cargo run --release --example design_space
//! ```

use anyhow::Result;

use sr_accel::analysis::{AreaModel, BufferBudget, BufferParams};
use sr_accel::benchkit::Table;
use sr_accel::config::ModelConfig;
use sr_accel::planner::{enumerate_candidates, SearchSpace};
use sr_accel::sim::engine::{layer_cycles, EngineGeometry};

fn main() -> Result<()> {
    let model = ModelConfig::apbn();
    let (lr_w, lr_h, workers) = (640usize, 360usize, 4usize);

    // ---- serving schedule frontier ----------------------------------
    // The exact candidate set `tune` searches for this geometry, ranked
    // by the analytic cycle + SRAM-staging cost model (best first).
    let space = SearchSpace::serving(lr_h, workers);
    let mut t = Table::new(
        &format!(
            "schedule frontier {lr_w}x{lr_h} x{} ({} workers, cost model)",
            model.scale, workers
        ),
        &["plan", "bands", "compute Mcyc", "staging MB", "score"],
    );
    for c in enumerate_candidates(lr_w, lr_h, &model, &space, workers) {
        t.row(&[
            c.plan.describe(),
            format!("{}", c.predicted.bands),
            format!("{:.2}", c.predicted.compute_cycles as f64 / 1e6),
            format!("{:.2}", c.predicted.staging_bytes as f64 / 1e6),
            format!("{:.0}", c.predicted.score),
        ]);
    }
    t.print();

    // ---- tile width sweep -------------------------------------------
    // Same enumeration API, restricted to the tilted executor: wider
    // tiles amortize the 2-column halo re-fetch (less staging traffic)
    // but cost quadratically more ping-pong SRAM and die area.
    let widths = [1usize, 2, 4, 8, 16, 32, 60];
    let space = SearchSpace::tile_ablation(lr_h, &widths);
    let mut sweep =
        enumerate_candidates(lr_w, lr_h, &model, &space, 1);
    sweep.sort_by_key(|c| c.plan.tile_cols);
    let area = AreaModel::default();
    let bias_bytes: usize = model.channels[1..].iter().sum::<usize>() * 4;
    let mut t = Table::new(
        "tile width sweep (R=60, tilted executor, analytic)",
        &["C", "SRAM KB", "staging MB/frame", "score", "area mm^2"],
    );
    for c in &sweep {
        let mut p = BufferParams::paper_tilted();
        p.tile_cols = c.plan.tile_cols.max(2);
        p.weight_bytes = model.weight_bytes() as usize + bias_bytes;
        let budget = BufferBudget::tilted(&p);
        let gates = area.gate_count(1260, 140);
        let mm2 = area.area_mm2_40nm(gates, budget.total_kb());
        t.row(&[
            format!("{}", c.plan.tile_cols),
            format!("{:.1}", budget.total_kb()),
            format!("{:.2}", c.predicted.staging_bytes as f64 / 1e6),
            format!("{:.0}", c.predicted.score),
            format!("{mm2:.2}"),
        ]);
    }
    t.print();

    // ---- PE-block count sweep (hypothetical re-architectures) --------
    let mut t2 = Table::new(
        "PE-block sweep (analytic, APBN layers, 60x8 tiles)",
        &["blocks", "MACs", "peak GMAC/s", "cycles/tile-stack", "util %"],
    );
    for blocks in [7usize, 14, 28, 56] {
        let geo = EngineGeometry {
            pe_blocks: blocks,
            macs_per_cycle: blocks * 45,
        };
        let mut cyc = 0u64;
        let mut ops = 0u64;
        let mut slots = 0u64;
        for w in model.channels.windows(2) {
            let c = layer_cycles(60, 8, w[0], w[1], &geo);
            cyc += c.cycles;
            ops += c.mac_ops;
            slots += c.mac_slots;
        }
        t2.row(&[
            format!("{blocks}"),
            format!("{}", blocks * 45),
            format!("{:.0}", blocks as f64 * 45.0 * 0.6),
            format!("{cyc}"),
            format!("{:.1}", 100.0 * ops as f64 / slots as f64),
        ]);
    }
    t2.print();
    println!(
        "\n28 blocks = the channel count of APBN's inner layers: fewer \
         blocks double the cycles; more blocks idle on cin<=28 — the \
         paper's utilization argument."
    );
    Ok(())
}
