//! Design-space exploration: sweep tile geometry and PE-block count,
//! mapping the SRAM / throughput / utilization frontier the paper's
//! Section IV.A argues about.  Shows why (C=8, R=60, 28 blocks) is the
//! published design point.
//!
//! ```sh
//! make artifacts && cargo run --release --example design_space
//! ```

use anyhow::Result;

use sr_accel::analysis::{AreaModel, BufferBudget, BufferParams};
use sr_accel::benchkit::Table;
use sr_accel::config::AcceleratorConfig;
use sr_accel::fusion::{FusionScheduler, TiltedScheduler};
use sr_accel::model::{load_apbnw, Tensor};
use sr_accel::runtime::artifacts_dir;
use sr_accel::sim::engine::{layer_cycles, EngineGeometry};
use sr_accel::util::Xoshiro256pp;

fn main() -> Result<()> {
    let qm = load_apbnw(&artifacts_dir().join("weights.apbnw"))?;
    let frame = {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut t = Tensor::new(120, 320, 3);
        rng.fill_u8(&mut t.data);
        t
    };

    // ---- tile width sweep -------------------------------------------
    let mut t = Table::new(
        "tile width sweep (R=60, measured on 120x320, scaled x4)",
        &["C", "SRAM KB", "fps@600MHz", "util %", "area mm^2"],
    );
    let area = AreaModel::default();
    for c in [1usize, 2, 4, 8, 16, 32, 60] {
        let acc = AcceleratorConfig {
            tile_cols: c,
            ..AcceleratorConfig::paper()
        };
        let mut p = BufferParams::paper_tilted();
        p.tile_cols = c.max(2);
        p.weight_bytes = qm.weight_bytes() + qm.bias_bytes();
        let budget = BufferBudget::tilted(&p);
        let res = TiltedScheduler::default().run_frame(&frame, &qm, &acc);
        let fps = 600e6 / (res.stats.compute_cycles as f64 * 4.0);
        let gates = area.gate_count(1260, 140);
        let mm2 = area.area_mm2_40nm(gates, budget.total_kb());
        t.row(&[
            format!("{c}"),
            format!("{:.1}", budget.total_kb()),
            format!("{fps:.1}"),
            format!("{:.1}", res.stats.utilization() * 100.0),
            format!("{mm2:.2}"),
        ]);
    }
    t.print();

    // ---- PE-block count sweep (hypothetical re-architectures) --------
    let mut t2 = Table::new(
        "PE-block sweep (analytic, APBN layers, 60x8 tiles)",
        &["blocks", "MACs", "peak GMAC/s", "cycles/tile-stack", "util %"],
    );
    let channels = [3usize, 28, 28, 28, 28, 28, 28, 27];
    for blocks in [7usize, 14, 28, 56] {
        let geo = EngineGeometry {
            pe_blocks: blocks,
            macs_per_cycle: blocks * 45,
        };
        let mut cyc = 0u64;
        let mut ops = 0u64;
        let mut slots = 0u64;
        for w in channels.windows(2) {
            let c = layer_cycles(60, 8, w[0], w[1], &geo);
            cyc += c.cycles;
            ops += c.mac_ops;
            slots += c.mac_slots;
        }
        t2.row(&[
            format!("{blocks}"),
            format!("{}", blocks * 45),
            format!("{:.0}", blocks as f64 * 45.0 * 0.6),
            format!("{cyc}"),
            format!("{:.1}", 100.0 * ops as f64 / slots as f64),
        ]);
    }
    t2.print();
    println!(
        "\n28 blocks = the channel count of APBN's inner layers: fewer \
         blocks double the cycles; more blocks idle on cin<=28 — the \
         paper's utilization argument."
    );
    Ok(())
}
